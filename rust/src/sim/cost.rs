//! First-order cycle-cost model (DESIGN.md S14).
//!
//! Maps a compiled model's per-operator MAC counts onto cycles for a given
//! (MCU, engine) pair:
//!
//! ```text
//! cycles(MF)   = Σ_op macs(op) · cpm(arch) · paging_factor
//!              + n_ops · mf_op_overhead + mf_invoke_overhead
//! cycles(TFLM) = Σ_op macs(op) · cpm(arch) · tflm_factor(arch, op_class)
//!              + n_ops · tflm_op_overhead + tflm_invoke_overhead
//! ```
//!
//! `cpm` is the *effective* cycles-per-MAC of MicroFlow's generated code on
//! that architecture (epilogue amortized in); `tflm_factor` captures the
//! vendor-optimized kernels (CMSIS-NN / ESP-NN help dense convolutions,
//! fall back to slow generic paths for depthwise-with-multiplier and pay
//! interpreter arithmetic on FC); the fixed overheads capture per-node
//! dispatch and per-invoke interpreter work.
//!
//! ## Calibration
//!
//! Constants are calibrated so the *ratios* reproduce the paper's Fig. 11
//! findings (absolute silicon numbers are not reproducible without the
//! boards — DESIGN.md §4):
//!
//! * sine: MicroFlow ≈ 10x faster (interpreter overhead dominates);
//! * speech: MicroFlow +9% (ESP32) / +15% (nRF52840);
//! * person: TFLM ≈ 6% faster (optimized dense-conv kernels);
//! * nRF52840 ≈ 3x faster than ESP32 wall-clock despite the 64 vs 240 MHz
//!   clocks (the ESP32's weak FPU / codegen — paper Sec. 6.2.3 [52]).
//!
//! The calibration is *verified against the real compiled models* in
//! `rust/tests/integration_sim.rs`.

use crate::compiler::pack;
use crate::compiler::plan::{CompiledModel, StepKind};
use crate::sim::mcu::{ArchClass, Mcu};

/// Which inference engine is being modeled.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Engine {
    MicroFlow,
    Tflm,
}

/// Operator cost class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpClass {
    FullyConnected,
    Conv,
    DepthwiseConv,
    Pool,
    Elementwise,
}

impl OpClass {
    pub fn of(kind: &StepKind) -> OpClass {
        match kind {
            StepKind::FullyConnected { .. } => OpClass::FullyConnected,
            StepKind::Conv2D { .. } => OpClass::Conv,
            StepKind::DepthwiseConv2D { .. } => OpClass::DepthwiseConv,
            StepKind::AveragePool2D { .. } => OpClass::Pool,
            _ => OpClass::Elementwise,
        }
    }
}

/// Per-architecture cost constants (see module docs for calibration).
#[derive(Clone, Copy, Debug)]
pub struct ArchCost {
    /// MicroFlow effective cycles per int8 MAC.
    pub cycles_per_mac: f64,
    /// MicroFlow fixed overheads (cycles).
    pub mf_op_overhead: f64,
    pub mf_invoke_overhead: f64,
    /// TFLM per-class MAC factors relative to MicroFlow's cpm.
    pub tflm_fc_factor: f64,
    pub tflm_conv_factor: f64,
    pub tflm_dw_factor: f64,
    pub tflm_pool_factor: f64,
    /// TFLM fixed overheads (cycles): per-node dispatch + per-invoke
    /// interpreter work (model walking, tensor checks).
    pub tflm_op_overhead: f64,
    pub tflm_invoke_overhead: f64,
}

/// Cost table per architecture class.
pub fn arch_cost(arch: ArchClass) -> ArchCost {
    match arch {
        // weak FPU + mediocre codegen: huge effective per-MAC cost, and a
        // very expensive interpreter pass (matches the paper's ESP32 notes)
        ArchClass::Xtensa => ArchCost {
            cycles_per_mac: 45.0,
            mf_op_overhead: 150.0,
            mf_invoke_overhead: 800.0,
            tflm_fc_factor: 1.30,
            tflm_conv_factor: 0.927,
            tflm_dw_factor: 1.07,
            tflm_pool_factor: 1.0,
            tflm_op_overhead: 1_200.0,
            tflm_invoke_overhead: 120_000.0,
        },
        ArchClass::CortexM7F => ArchCost {
            cycles_per_mac: 3.0,
            mf_op_overhead: 100.0,
            mf_invoke_overhead: 600.0,
            tflm_fc_factor: 1.30,
            tflm_conv_factor: 0.914,
            tflm_dw_factor: 1.125,
            tflm_pool_factor: 1.0,
            tflm_op_overhead: 900.0,
            tflm_invoke_overhead: 15_000.0,
        },
        ArchClass::CortexM4F => ArchCost {
            cycles_per_mac: 4.0,
            mf_op_overhead: 100.0,
            mf_invoke_overhead: 600.0,
            tflm_fc_factor: 1.30,
            tflm_conv_factor: 0.914,
            tflm_dw_factor: 1.125,
            tflm_pool_factor: 1.0,
            tflm_op_overhead: 1_200.0,
            tflm_invoke_overhead: 19_000.0,
        },
        // no FPU, no DSP: softfloat epilogues hurt both engines; no
        // optimized kernels for TFLM
        ArchClass::CortexM3 => ArchCost {
            cycles_per_mac: 15.0,
            mf_op_overhead: 180.0,
            mf_invoke_overhead: 1_000.0,
            tflm_fc_factor: 1.30,
            tflm_conv_factor: 1.15,
            tflm_dw_factor: 1.15,
            tflm_pool_factor: 1.1,
            tflm_op_overhead: 1_800.0,
            tflm_invoke_overhead: 30_000.0,
        },
        // 8-bit ALU: every 32-bit accumulate is many instructions
        ArchClass::Avr8 => ArchCost {
            cycles_per_mac: 60.0,
            mf_op_overhead: 400.0,
            mf_invoke_overhead: 2_000.0,
            tflm_fc_factor: 1.40,
            tflm_conv_factor: 1.40,
            tflm_dw_factor: 1.40,
            tflm_pool_factor: 1.3,
            tflm_op_overhead: 3_000.0,
            tflm_invoke_overhead: 60_000.0,
        },
    }
}

/// MAC count per cost class for a compiled model.
pub fn macs_by_class(compiled: &CompiledModel) -> Vec<(OpClass, u64)> {
    compiled
        .steps
        .iter()
        .map(|s| (OpClass::of(&s.kind), s.kind.macs(s.out_len)))
        .collect()
}

/// MACs the *MicroFlow* engine actually executes for a step — the cost
/// model knows the packed kernel's panel shape: Conv2D computes
/// `ceil(Cout/NR) * NR` lanes per output position (tail lanes are real
/// multiplies, just never written back), so its charge uses
/// [`pack::padded_lanes`]. Identical to the logical [`StepKind::macs`]
/// whenever `Cout % NR == 0` — true for every layer of the paper's three
/// models, which keeps the Fig. 11 calibration intact. FC's tail-aware
/// column view and depthwise's per-channel walk compute no padded lanes.
pub fn microflow_step_macs(kind: &StepKind, out_len: usize) -> u64 {
    match kind {
        StepKind::Conv2D { geo, filters, .. } => {
            (geo.out_h
                * geo.out_w
                * pack::padded_lanes(filters.c_out)
                * geo.k_h
                * geo.k_w
                * geo.in_c) as u64
        }
        other => other.macs(out_len),
    }
}

/// MACs for recomputing only `rows` output rows of a spatial step — the
/// pulsed (streaming) cost basis. Same padded-panel accounting as
/// [`microflow_step_macs`] with `out_h` replaced by `rows`, so the
/// planner's `V405` strict-savings obligation compares like with like.
/// Non-spatial steps charge `out_elems` (the delta slice for pointwise
/// steps; callers pass the full length for tail steps, which never pulse).
pub fn microflow_step_macs_rows(kind: &StepKind, rows: usize, out_elems: usize) -> u64 {
    match kind {
        StepKind::Conv2D { geo, filters, .. } => {
            (rows * geo.out_w * pack::padded_lanes(filters.c_out) * geo.k_h * geo.k_w * geo.in_c)
                as u64
        }
        StepKind::DepthwiseConv2D { geo, depth_multiplier, .. } => {
            (rows * geo.out_w * geo.in_c * depth_multiplier * geo.k_h * geo.k_w) as u64
        }
        StepKind::AveragePool2D { geo, .. } => {
            (rows * geo.out_w * geo.in_c * geo.k_h * geo.k_w) as u64
        }
        other => other.macs(out_elems),
    }
}

/// Modeled cycles for one inference.
pub fn inference_cycles(compiled: &CompiledModel, mcu: &Mcu, engine: Engine) -> f64 {
    let c = arch_cost(mcu.arch);
    let n_ops = compiled.steps.len() as f64;
    match engine {
        Engine::MicroFlow => {
            let paging_factor = if compiled.options.paging {
                compiled.page_plan.map(|p| p.slowdown_factor()).unwrap_or(1.0)
            } else {
                1.0
            };
            let mac_cycles: f64 = compiled
                .steps
                .iter()
                .map(|s| {
                    let m = microflow_step_macs(&s.kind, s.out_len) as f64 * c.cycles_per_mac;
                    if matches!(s.kind, StepKind::FullyConnected { paged: true, .. }) {
                        m * paging_factor
                    } else {
                        m
                    }
                })
                .sum();
            mac_cycles + n_ops * c.mf_op_overhead + c.mf_invoke_overhead
        }
        Engine::Tflm => {
            // without vendor kernels the generic reference paths are worse
            let (fc, conv, dw, pool) = if mcu.optimized_nn_kernels {
                (c.tflm_fc_factor, c.tflm_conv_factor, c.tflm_dw_factor, c.tflm_pool_factor)
            } else {
                (c.tflm_fc_factor, c.tflm_conv_factor.max(1.15), c.tflm_dw_factor.max(1.15), 1.1)
            };
            let mac_cycles: f64 = compiled
                .steps
                .iter()
                .map(|s| {
                    let factor = match OpClass::of(&s.kind) {
                        OpClass::FullyConnected => fc,
                        OpClass::Conv => conv,
                        OpClass::DepthwiseConv => dw,
                        OpClass::Pool => pool,
                        OpClass::Elementwise => 1.0,
                    };
                    s.kind.macs(s.out_len) as f64 * c.cycles_per_mac * factor
                })
                .sum();
            mac_cycles + n_ops * c.tflm_op_overhead + c.tflm_invoke_overhead
        }
    }
}

/// Modeled wall-clock seconds for one inference.
pub fn inference_seconds(compiled: &CompiledModel, mcu: &Mcu, engine: Engine) -> f64 {
    inference_cycles(compiled, mcu, engine) / mcu.clock_hz as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::plan::{CompileOptions, CompiledModel};
    use crate::format::mfb::MfbModel;
    use crate::sim::mcu::by_name;

    fn tiny() -> CompiledModel {
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        CompiledModel::compile(&m, CompileOptions::default()).unwrap()
    }

    #[test]
    fn tflm_overhead_dominates_tiny_models() {
        let c = tiny();
        let esp = by_name("ESP32").unwrap();
        let mf = inference_cycles(&c, esp, Engine::MicroFlow);
        let tflm = inference_cycles(&c, esp, Engine::Tflm);
        // a 6-MAC model: TFLM pays the full interpreter toll
        assert!(tflm / mf > 5.0, "ratio {}", tflm / mf);
    }

    #[test]
    fn paging_slows_microflow_down() {
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        let unpaged = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
        let paged = CompiledModel::compile(&m, CompileOptions { paging: true, ..Default::default() }).unwrap();
        let mcu = by_name("ATmega328").unwrap();
        assert!(
            inference_cycles(&paged, mcu, Engine::MicroFlow)
                > inference_cycles(&unpaged, mcu, Engine::MicroFlow)
        );
    }

    #[test]
    fn seconds_scale_with_clock() {
        let c = tiny();
        let esp = by_name("ESP32").unwrap();
        let cycles = inference_cycles(&c, esp, Engine::MicroFlow);
        let secs = inference_seconds(&c, esp, Engine::MicroFlow);
        assert!((secs - cycles / 240e6).abs() < 1e-12);
    }

    #[test]
    fn conv_cost_charges_whole_panels() {
        use crate::compiler::pack::{pack_conv2d, NR};
        use crate::format::mfb::Padding;
        use crate::kernels::view::ConvGeometry;
        use crate::tensor::quant::{FusedAct, PreComputed};

        let geo = ConvGeometry::new(6, 6, 2, 3, 3, 1, 1, Padding::Same).unwrap();
        let step = |c_out: usize| {
            let kkc = 3 * 3 * 2;
            let pc = PreComputed::fold(
                &vec![0; c_out],
                &vec![0; c_out],
                kkc,
                0.1,
                0,
                0.1,
                0,
                0.01,
                0,
                0.1,
                0,
                FusedAct::None,
            );
            crate::compiler::plan::StepKind::Conv2D {
                geo,
                filters: pack_conv2d(&vec![0i8; c_out * kkc], c_out, kkc),
                z_x: 0,
                pc,
            }
        };
        // c_out = 6 rounds up to 8 lanes; c_out = 8 is exact
        let padded = microflow_step_macs(&step(6), 6 * 6 * 6);
        let exact = microflow_step_macs(&step(8), 6 * 6 * 8);
        assert_eq!(padded, exact, "6 channels cost a full 2-panel walk");
        assert_eq!(exact, step(8).macs(6 * 6 * 8), "whole panels charge no padding");
        assert_eq!(padded / (6 * 6 * 3 * 3 * 2), NR as u64 * 2);
        // the logical MAC count (reporting/energy) stays unpadded
        assert_eq!(step(6).macs(6 * 6 * 6), (6 * 6 * 6 * 3 * 3 * 2) as u64);
    }

    #[test]
    fn every_arch_has_positive_costs() {
        use crate::sim::mcu::MCUS;
        for m in &MCUS {
            let c = arch_cost(m.arch);
            assert!(c.cycles_per_mac > 0.0);
            assert!(c.tflm_invoke_overhead > c.mf_invoke_overhead);
        }
    }
}
