//! The Table-4 MCU roster (DESIGN.md S14).
//!
//! Specs are the paper's Table 4; power draws are datasheet-typical active
//! currents at nominal voltage (used by the Table-6 energy model); the
//! per-architecture cost/code-size constants live in [`super::cost`] and
//! [`super::memory_model`].

/// Instruction-set / implementation class, driving cost and code-size
/// constants.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArchClass {
    /// 32-bit Xtensa LX6 (ESP32) — fast clock, weak FPU, mediocre codegen.
    Xtensa,
    /// ARM Cortex-M7F — dual-issue, caches, fast FPU.
    CortexM7F,
    /// ARM Cortex-M4F — DSP extensions, fast FPU.
    CortexM4F,
    /// ARM Cortex-M3 — no FPU (softfloat), no DSP.
    CortexM3,
    /// 8-bit AVR — 8-bit ALU, 2-cycle 8x8 multiply, softfloat.
    Avr8,
}

/// One microcontroller (a Table-4 row).
#[derive(Clone, Copy, Debug)]
pub struct Mcu {
    pub name: &'static str,
    pub board: &'static str,
    pub arch: ArchClass,
    pub flash_bytes: usize,
    pub ram_bytes: usize,
    pub clock_hz: u64,
    /// Typical active power (W) while crunching — drives Table 6.
    pub active_power_w: f64,
    /// Whether a TFLM port exists for this target (the paper could run
    /// TFLM only on ESP32 and nRF52840; Sec. 6.2.2).
    pub tflm_supported: bool,
    /// Whether the vendor ships optimized NN kernels TFLM can use
    /// (CMSIS-NN / ESP-NN — the person-detector advantage, Sec. 6.2.3).
    pub optimized_nn_kernels: bool,
}

/// The five paper devices, in the paper's performance order.
pub const MCUS: [Mcu; 5] = [
    Mcu {
        name: "ESP32",
        board: "Adafruit HUZZAH32",
        arch: ArchClass::Xtensa,
        flash_bytes: 4 * 1024 * 1024,
        ram_bytes: 328 * 1024,
        clock_hz: 240_000_000,
        active_power_w: 0.24,
        tflm_supported: true,
        optimized_nn_kernels: true, // ESP-NN
    },
    Mcu {
        name: "ATSAMV71",
        board: "SAM V71 Xplained Ultra",
        arch: ArchClass::CortexM7F,
        flash_bytes: 2 * 1024 * 1024,
        ram_bytes: 384 * 1024,
        clock_hz: 300_000_000,
        active_power_w: 0.165,
        tflm_supported: false,
        optimized_nn_kernels: true,
    },
    Mcu {
        name: "nRF52840",
        board: "Arduino Nano 33 BLE Sense",
        arch: ArchClass::CortexM4F,
        flash_bytes: 1024 * 1024,
        ram_bytes: 256 * 1024,
        clock_hz: 64_000_000,
        active_power_w: 0.017,
        tflm_supported: true,
        optimized_nn_kernels: true, // CMSIS-NN
    },
    Mcu {
        name: "LM3S6965",
        board: "QEMU emulation",
        arch: ArchClass::CortexM3,
        flash_bytes: 256 * 1024,
        ram_bytes: 64 * 1024,
        clock_hz: 50_000_000,
        active_power_w: 0.12,
        tflm_supported: false,
        optimized_nn_kernels: false,
    },
    Mcu {
        name: "ATmega328",
        board: "Arduino Uno",
        arch: ArchClass::Avr8,
        flash_bytes: 32 * 1024,
        ram_bytes: 2 * 1024,
        clock_hz: 20_000_000,
        active_power_w: 0.045,
        tflm_supported: false,
        optimized_nn_kernels: false,
    },
];

/// Look up an MCU by name.
pub fn by_name(name: &str) -> Option<&'static Mcu> {
    MCUS.iter().find(|m| m.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roster_matches_table4() {
        assert_eq!(MCUS.len(), 5);
        let atmega = by_name("ATmega328").unwrap();
        assert_eq!(atmega.flash_bytes, 32 * 1024);
        assert_eq!(atmega.ram_bytes, 2 * 1024);
        let esp = by_name("esp32").unwrap();
        assert_eq!(esp.clock_hz, 240_000_000);
    }

    #[test]
    fn only_esp32_and_nrf_have_tflm_ports() {
        let supported: Vec<&str> =
            MCUS.iter().filter(|m| m.tflm_supported).map(|m| m.name).collect();
        assert_eq!(supported, vec!["ESP32", "nRF52840"]);
    }

    #[test]
    fn descending_capability_order() {
        assert!(MCUS.windows(2).all(|w| w[0].flash_bytes >= w[1].flash_bytes));
    }
}
