//! Energy model (paper Sec. 6.2.4, Table 6; DESIGN.md S14).
//!
//! The paper's own observation is the model: the two engines execute the
//! same kinds of operations on the same peripherals, so average power is
//! engine-independent and energy is simply `P_active × t_inference`. Our
//! per-MCU `active_power_w` values are datasheet-typical; the Table-6
//! *shape* (energy ∝ time; MicroFlow ahead except on the person detector)
//! follows from the cost model.

use crate::compiler::plan::CompiledModel;
use crate::sim::cost::{inference_seconds, Engine};
use crate::sim::mcu::Mcu;

/// Energy per inference in watt-hours.
pub fn inference_energy_wh(compiled: &CompiledModel, mcu: &Mcu, engine: Engine) -> f64 {
    let secs = inference_seconds(compiled, mcu, engine);
    mcu.active_power_w * secs / 3600.0
}

/// Energy per inference in joules.
pub fn inference_energy_j(compiled: &CompiledModel, mcu: &Mcu, engine: Engine) -> f64 {
    mcu.active_power_w * inference_seconds(compiled, mcu, engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::plan::{CompileOptions, CompiledModel};
    use crate::format::mfb::MfbModel;
    use crate::sim::mcu::by_name;

    fn tiny() -> CompiledModel {
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        CompiledModel::compile(&m, CompileOptions::default()).unwrap()
    }

    #[test]
    fn energy_proportional_to_time() {
        let c = tiny();
        let esp = by_name("ESP32").unwrap();
        let t_mf = inference_seconds(&c, esp, Engine::MicroFlow);
        let t_tf = inference_seconds(&c, esp, Engine::Tflm);
        let e_mf = inference_energy_wh(&c, esp, Engine::MicroFlow);
        let e_tf = inference_energy_wh(&c, esp, Engine::Tflm);
        assert!((e_tf / e_mf - t_tf / t_mf).abs() < 1e-9);
    }

    #[test]
    fn joules_and_wh_agree() {
        let c = tiny();
        let esp = by_name("ESP32").unwrap();
        let j = inference_energy_j(&c, esp, Engine::MicroFlow);
        let wh = inference_energy_wh(&c, esp, Engine::MicroFlow);
        assert!((j - wh * 3600.0).abs() < 1e-12);
    }
}
