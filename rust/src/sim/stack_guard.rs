//! Stack-overflow protection model — paper Sec. 4.4 (flip-link).
//!
//! On bare-metal ARM Cortex-M the default memory layout places the stack
//! *above* the static data (`.data`/`.bss`), growing down towards it: an
//! overflow silently corrupts statics (undefined behaviour). The paper
//! adopts `flip-link`, which flips the layout so the stack sits *below*
//! the statics and an overflow walks off the bottom of RAM — a bus fault
//! the firmware can catch. Currently Cortex-M only, exactly as in the
//! paper.
//!
//! This module models both layouts for the simulated devices: given a
//! device, a static-data size and a peak stack demand, it reports whether
//! an overflow occurs and — crucially — whether it is *detected* (hardware
//! exception) or *silent corruption*. The deploy CLI and the fleet example
//! surface it; `integration_sim.rs` pins the Sec. 4.4 claims.

use crate::sim::mcu::{ArchClass, Mcu};

/// RAM layout strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StackLayout {
    /// Default linker script: statics at the bottom, stack on top growing
    /// down into them.
    Default,
    /// flip-link: stack at the bottom growing down past the RAM boundary.
    Flipped,
}

/// Outcome of running with a given stack demand.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StackOutcome {
    /// Stack fits; no overflow.
    Ok { headroom: usize },
    /// Overflow hit the RAM boundary → hardware exception (HardFault /
    /// bus error) the runtime can handle. Safe failure.
    DetectedOverflow { deficit: usize },
    /// Overflow walked into the statics region undetected. Undefined
    /// behaviour — the failure mode Sec. 4.4 eliminates.
    SilentCorruption { deficit: usize },
}

impl StackOutcome {
    pub fn is_safe(&self) -> bool {
        !matches!(self, StackOutcome::SilentCorruption { .. })
    }
}

/// Whether flip-link supports this architecture (Cortex-M only, like the
/// paper's tooling note).
pub fn flip_link_available(arch: ArchClass) -> bool {
    matches!(arch, ArchClass::CortexM7F | ArchClass::CortexM4F | ArchClass::CortexM3)
}

/// Evaluate a stack demand against a device and layout.
///
/// `static_bytes` is the `.data`+`.bss` footprint (the engine's base RAM
/// plus buffers); `stack_demand` the peak stack use of the inference.
pub fn evaluate(
    mcu: &Mcu,
    layout: StackLayout,
    static_bytes: usize,
    stack_demand: usize,
) -> StackOutcome {
    let ram = mcu.ram_bytes;
    let avail = ram.saturating_sub(static_bytes);
    if stack_demand <= avail {
        return StackOutcome::Ok { headroom: avail - stack_demand };
    }
    let deficit = stack_demand - avail;
    match layout {
        // stack grows down into .data/.bss: no MPU fence, silent
        StackLayout::Default => StackOutcome::SilentCorruption { deficit },
        // stack grows past the bottom of RAM: bus fault on Cortex-M;
        // other architectures have no such fence even flipped
        StackLayout::Flipped => {
            if flip_link_available(mcu.arch) {
                StackOutcome::DetectedOverflow { deficit }
            } else {
                StackOutcome::SilentCorruption { deficit }
            }
        }
    }
}

/// The layout MicroFlow firmware uses on a device: flipped when the
/// toolchain supports it (paper: flip-link on Cortex-M), default elsewhere.
pub fn microflow_layout(mcu: &Mcu) -> StackLayout {
    if flip_link_available(mcu.arch) {
        StackLayout::Flipped
    } else {
        StackLayout::Default
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::mcu::by_name;

    #[test]
    fn fits_when_demand_is_small() {
        let nrf = by_name("nRF52840").unwrap();
        let o = evaluate(nrf, StackLayout::Flipped, 40_000, 10_000);
        assert!(matches!(o, StackOutcome::Ok { .. }));
    }

    #[test]
    fn default_layout_corrupts_silently() {
        let nrf = by_name("nRF52840").unwrap();
        let o = evaluate(nrf, StackLayout::Default, 200_000, 100_000);
        assert!(matches!(o, StackOutcome::SilentCorruption { .. }));
        assert!(!o.is_safe());
    }

    #[test]
    fn flipped_layout_faults_detectably_on_cortex_m() {
        let nrf = by_name("nRF52840").unwrap();
        let o = evaluate(nrf, StackLayout::Flipped, 200_000, 100_000);
        assert_eq!(o, StackOutcome::DetectedOverflow { deficit: 100_000 - (256 * 1024 - 200_000) });
        assert!(o.is_safe());
    }

    #[test]
    fn flip_link_is_cortex_m_only() {
        assert!(flip_link_available(ArchClass::CortexM4F));
        assert!(flip_link_available(ArchClass::CortexM3));
        assert!(!flip_link_available(ArchClass::Avr8));
        assert!(!flip_link_available(ArchClass::Xtensa));
        // the paper's limitation verbatim: only Cortex-M targets get the
        // protection today
        let esp = by_name("ESP32").unwrap();
        let o = evaluate(esp, StackLayout::Flipped, 320_000, 20_000);
        assert!(matches!(o, StackOutcome::SilentCorruption { .. }));
    }

    #[test]
    fn microflow_picks_flipped_where_possible() {
        assert_eq!(microflow_layout(by_name("ATSAMV71").unwrap()), StackLayout::Flipped);
        assert_eq!(microflow_layout(by_name("ATmega328").unwrap()), StackLayout::Default);
    }
}
