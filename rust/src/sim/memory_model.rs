//! Flash / RAM accounting for both engines on each MCU (DESIGN.md S14;
//! paper Sec. 6.2.2, Fig. 9/10).
//!
//! The *variable* parts come from the real algorithms in this repo:
//!
//! * MicroFlow RAM: the static planner's peak live set
//!   ([`crate::compiler::memory::MemoryPlan::peak`]) — or the page plan's
//!   footprint under paging;
//! * TFLM RAM: the arena planner's size ([`crate::interp::arena`]) plus
//!   per-tensor/per-node interpreter structures;
//! * MicroFlow Flash payload: weights + folded constants
//!   ([`CompiledModel::weight_bytes`]) — names/options/versions stripped;
//! * TFLM Flash payload: the **entire model container**
//!   ([`MfbModel::file_bytes`]) since the interpreter reads it at runtime.
//!
//! The *fixed* parts are per-architecture code-size constants (engine code,
//! kernel code, firmware baseline), calibrated to the paper's Fig. 9
//! anchors: MicroFlow sine on ATmega328 = 13.6 kB Flash / 1.7 kB RAM;
//! ~65% Flash saving vs TFLM on ESP32; TFLM sine RAM on nRF52840 ≈ 45.7 kB
//! vs MicroFlow ≈ 5.3 kB.

use std::collections::BTreeSet;

use crate::compiler::plan::CompiledModel;
use crate::format::mfb::{MfbModel, OpCode};
use crate::interp::arena::ArenaPlan;
use crate::sim::cost::Engine;
use crate::sim::mcu::{ArchClass, Mcu};

/// Code-size constants (bytes) per architecture class.
#[derive(Clone, Copy, Debug)]
pub struct CodeSize {
    /// MicroFlow runtime core (plan walker + requant helpers).
    pub mf_core: usize,
    /// MicroFlow per-used-operator kernel code.
    pub mf_kernel: usize,
    /// TFLM interpreter core (parser, allocator, dispatcher).
    pub tflm_core: usize,
    /// TFLM per-registered-kernel code (ALL kernels are linked).
    pub tflm_kernel: usize,
    /// Bare firmware baseline (vectors, runtime init, clock setup).
    pub firmware: usize,
    /// Base RAM: stack + engine statics.
    pub mf_base_ram: usize,
    /// TFLM base RAM: interpreter object, allocator, framework buffers.
    pub tflm_base_ram: usize,
}

/// Per-architecture code sizes. 32-bit Thumb/Xtensa code is denser than
/// AVR for 32-bit arithmetic; AVR pays heavily for int32/float emulation.
pub fn code_size(arch: ArchClass) -> CodeSize {
    match arch {
        ArchClass::Xtensa => CodeSize {
            mf_core: 7_000,
            mf_kernel: 1_800,
            tflm_core: 38_000,
            tflm_kernel: 2_600,
            firmware: 9_000,
            mf_base_ram: 4_800,
            tflm_base_ram: 40_000,
        },
        ArchClass::CortexM7F | ArchClass::CortexM4F => CodeSize {
            mf_core: 6_000,
            mf_kernel: 1_500,
            tflm_core: 34_000,
            tflm_kernel: 2_400,
            firmware: 8_000,
            mf_base_ram: 4_900,
            tflm_base_ram: 40_000,
        },
        ArchClass::CortexM3 => CodeSize {
            mf_core: 6_500,
            mf_kernel: 1_600,
            tflm_core: 36_000,
            tflm_kernel: 2_500,
            firmware: 8_000,
            mf_base_ram: 4_000,
            tflm_base_ram: 38_000,
        },
        ArchClass::Avr8 => CodeSize {
            mf_core: 4_200,
            mf_kernel: 2_100,
            tflm_core: 46_000,
            tflm_kernel: 3_200,
            firmware: 5_800,
            mf_base_ram: 1_450,
            tflm_base_ram: 30_000,
        },
    }
}

/// Number of TFLM kernels linked by the all-ops resolver (Flash cost paid
/// regardless of the model).
pub const TFLM_REGISTERED_KERNELS: usize = 8;

/// Per-tensor and per-node interpreter RAM structures (TFLM's
/// `TfLiteTensor` / node bookkeeping).
pub const TFLM_TENSOR_STRUCT: usize = 64;
pub const TFLM_NODE_STRUCT: usize = 48;

/// RAM the interpreter's prepared per-node userdata occupies: our
/// interpreter (like TFLM kernels) unpacks each weighted node's bias into
/// i32s at `AllocateTensors` time and keeps it for the interpreter's
/// lifetime (`interp::resolver::NodeData`), so the memory model charges
/// 4 bytes per bias element for FullyConnected / Conv2D /
/// DepthwiseConv2D nodes. (Multipliers, geometry and bounds fit inside
/// [`TFLM_NODE_STRUCT`].)
pub fn tflm_prepared_node_bytes(model: &MfbModel) -> usize {
    model
        .operators
        .iter()
        .filter(|op| {
            matches!(op.opcode, OpCode::FullyConnected | OpCode::Conv2D | OpCode::DepthwiseConv2D)
        })
        .filter_map(|op| op.input(2).ok())
        .map(|b| model.tensors[b].numel() * 4)
        .sum()
}

/// A computed memory footprint.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemoryFootprint {
    pub flash: usize,
    pub ram: usize,
}

/// Why a deployment doesn't fit (the paper's "not enough memory" errors).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FitError {
    /// No port of the engine exists for this target.
    Unsupported,
    FlashOverflow { need: usize, have: usize },
    RamOverflow { need: usize, have: usize },
}

impl std::fmt::Display for FitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FitError::Unsupported => write!(f, "no framework port for this target"),
            FitError::FlashOverflow { need, have } => {
                write!(f, "not enough Flash: need {need} B, have {have} B")
            }
            FitError::RamOverflow { need, have } => {
                write!(f, "not enough RAM: need {need} B, have {have} B")
            }
        }
    }
}

/// Distinct operator kinds used by a compiled model (MicroFlow links only
/// these kernels — the compiler-based Flash win).
fn used_kernel_kinds(compiled: &CompiledModel) -> usize {
    let kinds: BTreeSet<&'static str> = compiled.steps.iter().map(|s| s.kind.name()).collect();
    kinds.len()
}

/// MicroFlow footprint on an MCU.
///
/// RAM charge per operator:
///
/// * On memory-mapped-Flash architectures (ARM, Xtensa) kernels stream
///   weights straight from Flash, so each step is charged its executor
///   live set (input + output + view scratch) — the `MemoryPlan` numbers.
/// * On the Harvard-architecture AVR, Flash is not data-addressable
///   (bytewise `LPM` only), so FullyConnected layers stage their working
///   set in RAM. Unpaged that is the paper's footnote-13 costing (weights
///   + int32 accumulators + vectors ≈ 5 kB for 32x32); paged it is one
///   page (163 B for K = 32) — Sec. 4.3's entire raison d'être.
/// * Paging, when enabled, caps every FC at one page on any architecture.
pub fn microflow_footprint(compiled: &CompiledModel, mcu: &Mcu) -> MemoryFootprint {
    use crate::compiler::paging::PagePlan;
    use crate::compiler::plan::StepKind;

    let cs = code_size(mcu.arch);
    let flash = cs.firmware
        + cs.mf_core
        + cs.mf_kernel * used_kernel_kinds(compiled)
        + compiled.weight_bytes();
    let avr = mcu.arch == ArchClass::Avr8;
    let peak = compiled
        .memory
        .per_step
        .iter()
        .zip(&compiled.steps)
        .map(|(m, s)| match &s.kind {
            StepKind::FullyConnected { k, n, paged, .. } => {
                if *paged {
                    PagePlan::paged_ram(*k)
                } else if avr {
                    PagePlan::unpaged_ram(*k, *n)
                } else {
                    m.live()
                }
            }
            _ => m.live(),
        })
        .max()
        .unwrap_or(0);
    MemoryFootprint { flash, ram: cs.mf_base_ram + peak }
}

/// TFLM footprint on an MCU: full container resident in Flash, arena +
/// interpreter structures + prepared node userdata in RAM.
pub fn tflm_footprint(model: &MfbModel, arena: &ArenaPlan, mcu: &Mcu) -> MemoryFootprint {
    let cs = code_size(mcu.arch);
    let flash = cs.firmware
        + cs.tflm_core
        + cs.tflm_kernel * TFLM_REGISTERED_KERNELS
        + model.file_bytes;
    let ram = cs.tflm_base_ram
        + arena.arena_size
        + model.tensors.len() * TFLM_TENSOR_STRUCT
        + model.operators.len() * TFLM_NODE_STRUCT
        + tflm_prepared_node_bytes(model);
    MemoryFootprint { flash, ram }
}

/// Check whether a footprint fits a device for a given engine.
pub fn fits(mcu: &Mcu, engine: Engine, fp: MemoryFootprint) -> Result<(), FitError> {
    if engine == Engine::Tflm && !mcu.tflm_supported {
        return Err(FitError::Unsupported);
    }
    if fp.flash > mcu.flash_bytes {
        return Err(FitError::FlashOverflow { need: fp.flash, have: mcu.flash_bytes });
    }
    if fp.ram > mcu.ram_bytes {
        return Err(FitError::RamOverflow { need: fp.ram, have: mcu.ram_bytes });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::plan::{CompileOptions, CompiledModel};
    use crate::format::mfb::MfbModel;
    use crate::interp::arena::ArenaPlan;
    use crate::sim::mcu::by_name;

    fn tiny() -> (MfbModel, CompiledModel, ArenaPlan) {
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
        let a = ArenaPlan::plan(&m).unwrap();
        (m, c, a)
    }

    #[test]
    fn microflow_flash_smaller_than_tflm() {
        let (m, c, a) = tiny();
        for mcu in crate::sim::mcu::MCUS.iter() {
            let mf = microflow_footprint(&c, mcu);
            let tf = tflm_footprint(&m, &a, mcu);
            assert!(mf.flash < tf.flash, "{}: {} vs {}", mcu.name, mf.flash, tf.flash);
            assert!(mf.ram < tf.ram, "{}: {} vs {}", mcu.name, mf.ram, tf.ram);
        }
    }

    #[test]
    fn tflm_ram_charges_prepared_node_userdata() {
        // regression (ROADMAP): the interpreter caches each weighted
        // node's bias as i32 userdata at prepare time; the memory model
        // must charge it. The tiny model has one FC with a 3-element
        // bias -> exactly 12 bytes, and the full RAM formula is pinned.
        let (m, _, a) = tiny();
        assert_eq!(tflm_prepared_node_bytes(&m), 12);
        let nrf = by_name("nRF52840").unwrap();
        let fp = tflm_footprint(&m, &a, nrf);
        let cs = code_size(nrf.arch);
        assert_eq!(
            fp.ram,
            cs.tflm_base_ram
                + a.arena_size
                + m.tensors.len() * TFLM_TENSOR_STRUCT
                + m.operators.len() * TFLM_NODE_STRUCT
                + 12
        );
    }

    #[test]
    fn prepared_node_bytes_skip_unweighted_ops() {
        let mut m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        // turn the op into a (malformed but countable) Relu: no bias input
        m.operators[0].opcode = crate::format::mfb::OpCode::Relu;
        assert_eq!(tflm_prepared_node_bytes(&m), 0);
    }

    #[test]
    fn tflm_unsupported_off_esp_and_nrf() {
        let (m, _, a) = tiny();
        let atmega = by_name("ATmega328").unwrap();
        let fp = tflm_footprint(&m, &a, atmega);
        assert_eq!(fits(atmega, Engine::Tflm, fp), Err(FitError::Unsupported));
    }

    #[test]
    fn tiny_model_fits_atmega_with_microflow() {
        let (_, c, _) = tiny();
        let atmega = by_name("ATmega328").unwrap();
        let fp = microflow_footprint(&c, atmega);
        assert!(fits(atmega, Engine::MicroFlow, fp).is_ok(), "{fp:?}");
    }

    #[test]
    fn flash_overflow_is_reported_with_sizes() {
        let err = FitError::FlashOverflow { need: 100, have: 50 };
        assert!(err.to_string().contains("100"));
    }

    #[test]
    fn paging_reduces_modeled_ram() {
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        // tiny model's working set is already small, so construct the
        // comparison at the PagePlan level: covered by paging tests; here
        // just ensure the paged path is taken
        let paged = CompiledModel::compile(&m, CompileOptions { paging: true, ..Default::default() }).unwrap();
        let atmega = by_name("ATmega328").unwrap();
        let fp = microflow_footprint(&paged, atmega);
        assert!(fp.ram >= code_size(atmega.arch).mf_base_ram);
    }
}
