//! The MicroFlow Compiler (paper Sec. 3.3; DESIGN.md S5-S8).
//!
//! Pipeline (Fig. 2/4 of the paper):
//!
//! ```text
//! MFB bytes ──parse──▶ MfbModel (lossless IR) ──preprocess──▶ folded
//! constants (Eq. 4/7/10/13) ──pack──▶ kernel-layout weight panels
//! (conv NR-panels, dw transpose, FC panel view) ──plan──▶ ExecutionPlan
//! + MemoryPlan (+ PagePlan when paging is requested)
//! ```
//!
//! The paper runs this inside a procedural macro at `rustc` time; here the
//! identical pipeline runs once at model load, producing an immutable
//! [`plan::CompiledModel`] (see DESIGN.md §4 for why this substitution
//! preserves the compile-time/run-time split: all shape checks, constant
//! folding and memory sizing happen *before* the first inference, and the
//! per-inference work is exactly the generated-code equivalent).
//!
//! Everything the runtime does not need — tensor names, operator versions,
//! metadata, the serialized container itself — is dropped here; the
//! interpreter baseline ([`crate::interp`]) keeps all of it, which is the
//! memory story of Fig. 9/10.

pub mod memory;
pub mod pack;
pub mod paging;
pub mod plan;
pub mod preprocess;
pub mod pulse;
pub mod verify;

pub use memory::MemoryPlan;
pub use pack::{PackedConvFilters, NR};
pub use paging::PagePlan;
pub use plan::{CompiledModel, CompileOptions, Step, StepKind};
pub use pulse::{verify_pulse, PulsePlan, PulseStep, PulseStepKind};
pub use verify::{verify, Certificate, StepCert, VerifyError, ERROR_CODE_TABLE};
