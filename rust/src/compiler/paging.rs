//! Paging planner (paper Sec. 4.3, Fig. 6; DESIGN.md S8).
//!
//! A *page* holds everything needed to produce **one output neuron** of a
//! FullyConnected layer: its K weights, its bias/constants, and the working
//! accumulator. Pages are staged Flash→RAM one at a time, trading time for
//! a working set small enough for a 2 kB device (ATmega328).
//!
//! RAM accounting follows the paper's own costing (footnote 13):
//!
//! * unpaged: `K*N` weight bytes + `4*K*N` accumulator bytes + `3*N`
//!   (bias/input/output vectors) — ≈ 5 kB for the 32×32 example;
//! * paged (N pages): `K + 4*K + 3` per page — 163 bytes for K = 32.

/// Paging plan for the FullyConnected layers of a model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PagePlan {
    /// Total pages across all paged layers (one per output neuron).
    pub pages: usize,
    /// Largest single-page RAM footprint (bytes, paper costing).
    pub page_bytes: usize,
    /// RAM the same layers would need unpaged (paper costing).
    pub unpaged_bytes: usize,
}

impl PagePlan {
    /// Plan one FC layer of shape `[K, N]`.
    pub fn for_fully_connected(k: usize, n: usize) -> PagePlan {
        PagePlan {
            pages: n,
            page_bytes: Self::paged_ram(k),
            unpaged_bytes: Self::unpaged_ram(k, n),
        }
    }

    /// Paper footnote-13 unpaged costing: weights + int32 accumulators +
    /// bias/input/output vectors.
    pub fn unpaged_ram(k: usize, n: usize) -> usize {
        k * n + 4 * k * n + 3 * n
    }

    /// Paper paged costing: one page of weights + its accumulators + the
    /// three per-neuron scalars.
    pub fn paged_ram(k: usize) -> usize {
        k + 4 * k + 3
    }

    /// Combine with another layer's plan (a model may page several layers).
    pub fn merge(self, other: PagePlan) -> PagePlan {
        PagePlan {
            pages: self.pages + other.pages,
            page_bytes: self.page_bytes.max(other.page_bytes),
            unpaged_bytes: self.unpaged_bytes.max(other.unpaged_bytes),
        }
    }

    /// Paging slowdown model: each page staging costs one pass over K
    /// weight bytes of Flash reads that the unpaged kernel amortizes.
    /// Returns the multiplicative execution-time factor (≥ 1).
    pub fn slowdown_factor(&self) -> f64 {
        // staging a page touches every weight byte once more than the
        // streaming unpaged kernel: ~2x weight traffic on AVR-class parts
        2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_footnote_13() {
        // 32-neuron dense layer, 32 inputs: ~5 kB unpaged ...
        let unpaged = PagePlan::unpaged_ram(32, 32);
        assert_eq!(unpaged, 32 * 32 + 4 * 32 * 32 + 3 * 32); // 5216 ≈ 5 kB
        assert!(unpaged > 5000 && unpaged < 5500);
        // ... and exactly 163 bytes per page
        assert_eq!(PagePlan::paged_ram(32), 163);
    }

    #[test]
    fn paged_fits_atmega_unpaged_does_not() {
        const ATMEGA_RAM: usize = 2048;
        let plan = PagePlan::for_fully_connected(32, 32);
        assert!(plan.unpaged_bytes > ATMEGA_RAM);
        assert!(plan.page_bytes < ATMEGA_RAM);
    }

    #[test]
    fn merge_takes_max_footprint_and_sums_pages() {
        let a = PagePlan::for_fully_connected(32, 32);
        let b = PagePlan::for_fully_connected(64, 8);
        let m = a.merge(b);
        assert_eq!(m.pages, 40);
        assert_eq!(m.page_bytes, PagePlan::paged_ram(64));
    }
}
