//! Compile-time weight packing (paper Sec. 3.3's pre-processing extended
//! from *constants* to *layout*; DESIGN.md S9).
//!
//! The planner runs this pass once per weighted step, rewriting the
//! container's flash image into the layout the register-tiled kernels
//! ([`crate::kernels::microkernel`]) consume:
//!
//! * **Conv2D / pointwise** — `[Cout, KH*KW*Cin]` row-major filters are
//!   re-laid into output-channel panels of width [`NR`]:
//!   `[ceil(Cout/NR)][kkc][NR]`, channel-interleaved so the inner loop
//!   reads `NR` weights contiguously per input byte. The tail panel is
//!   zero-padded to `NR` lanes (computed, never written back).
//! * **DepthwiseConv2D** — the container's `[KH*KW, Cout]` filters are
//!   transposed once to `[Cout, KH*KW]` so every per-channel dot streams
//!   its filter contiguously. This used to happen at each call site;
//!   it is now part of the one compile-time pass.
//! * **FullyConnected** — weights stay `[K, N]` (each row already holds
//!   `N` contiguous per-channel weights, and the paged executor needs the
//!   container layout); the kernel walks them through a **tail-aware panel
//!   view** described by [`fc_panels`]: `n / NR` full register-tiled
//!   panels plus one `n % NR`-wide tail walk.
//!
//! ## Bit-exactness contract
//!
//! Packing permutes *where* a weight lives, never its value, and the
//! kernels accumulate in exact i32 arithmetic — so packed execution is
//! **bit-identical** to the unpacked reference order, and the engine's
//! exact-equality contract with the JAX golden path
//! (`python/compile/kernels/ref.py`, gated by
//! `tests/integration_engine.rs::engine_is_bit_exact_vs_jax_golden_on_all_models`)
//! is preserved exactly — `assert_eq!`, not within-one-unit. The
//! randomized oracle suite `tests/pack_equivalence.rs` pins this per
//! kernel, including `c_out % NR != 0` tails, 1x1 filters, SAME/VALID
//! padding and stride 2.

pub use crate::kernels::microkernel::{fc_panels, PackedConvFilters, NR};

/// Pack `[Cout, kkc]` row-major conv filters into `NR`-wide
/// output-channel panels (`kkc = KH*KW*Cin`; pointwise is `kkc = Cin`).
pub fn pack_conv2d(filters: &[i8], c_out: usize, kkc: usize) -> PackedConvFilters {
    assert_eq!(filters.len(), c_out * kkc, "filter payload doesn't match [Cout, KH*KW*Cin]");
    let panels = c_out.div_ceil(NR);
    let mut data = vec![0i8; panels * kkc * NR];
    for co in 0..c_out {
        let (p, r) = (co / NR, co % NR);
        let src = &filters[co * kkc..(co + 1) * kkc];
        let dst = &mut data[p * kkc * NR..(p + 1) * kkc * NR];
        for (k, &v) in src.iter().enumerate() {
            dst[k * NR + r] = v;
        }
    }
    let packed = PackedConvFilters { c_out, kkc, data };
    // producer-side enforcement of the panel-image invariant the
    // certifier proves statically (compiler::verify, V104) and
    // PackedConvFilters::panel() debug-asserts at the consumer: the
    // image holds exactly ceil(c_out/NR) panels of [kkc][NR] bytes
    assert_eq!(
        packed.data.len(),
        packed.panels() * packed.kkc * NR,
        "packed conv image size must equal panels * kkc * NR"
    );
    packed
}

/// Transpose container-layout depthwise filters `[KH*KW, Cout]` to the
/// kernel's channel-major `[Cout, KH*KW]` — one pass, at compile time.
pub fn pack_depthwise(w: &[i8], kk: usize, c_out: usize) -> Vec<i8> {
    assert_eq!(w.len(), kk * c_out, "dw filter payload doesn't match [KH*KW, Cout]");
    let mut out = vec![0i8; kk * c_out];
    for t in 0..kk {
        for co in 0..c_out {
            out[co * kk + t] = w[t * c_out + co];
        }
    }
    out
}

/// Output-channel lanes the packed conv kernel actually computes:
/// `ceil(c_out / NR) * NR` — `c_out` rounded up to whole panels. The cost
/// model charges conv MACs on this number (identical to `c_out` whenever
/// `c_out % NR == 0`, which holds for every layer of the paper's models).
pub fn padded_lanes(c_out: usize) -> usize {
    c_out.div_ceil(NR) * NR
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn conv_panels_interleave_channels() {
        // Cout=2, kkc=3: F = [[1,2,3],[4,5,6]] -> one panel
        // [k0: 1,4,0,0][k1: 2,5,0,0][k2: 3,6,0,0]
        let pf = pack_conv2d(&[1, 2, 3, 4, 5, 6], 2, 3);
        assert_eq!(pf.panels(), 1);
        assert_eq!(pf.panel_width(0), 2);
        assert_eq!(pf.data, vec![1, 4, 0, 0, 2, 5, 0, 0, 3, 6, 0, 0]);
    }

    #[test]
    fn conv_packing_is_a_permutation_plus_zero_tail() {
        let mut rng = Prng::new(11);
        for &(c_out, kkc) in &[(1usize, 5usize), (4, 9), (6, 3), (13, 8)] {
            let f = rng.i8_vec(c_out * kkc);
            let pf = pack_conv2d(&f, c_out, kkc);
            assert_eq!(pf.data.len(), c_out.div_ceil(NR) * kkc * NR);
            // every original weight is findable at its packed slot
            for co in 0..c_out {
                let (p, r) = (co / NR, co % NR);
                for k in 0..kkc {
                    assert_eq!(pf.panel(p)[k * NR + r], f[co * kkc + k], "co {co} k {k}");
                }
            }
            // tail lanes are zero
            let last = pf.panels() - 1;
            for r in pf.panel_width(last)..NR {
                for k in 0..kkc {
                    assert_eq!(pf.panel(last)[k * NR + r], 0);
                }
            }
        }
    }

    #[test]
    fn depthwise_transpose_round_trips() {
        // [KK=2, Cout=3]: [[1,2,3],[4,5,6]] -> [Cout, KK] = [1,4,2,5,3,6]
        let t = pack_depthwise(&[1, 2, 3, 4, 5, 6], 2, 3);
        assert_eq!(t, vec![1, 4, 2, 5, 3, 6]);
    }

    #[test]
    fn fc_panel_view_covers_every_width() {
        assert_eq!(fc_panels(1), (0, 1));
        assert_eq!(fc_panels(4), (1, 0));
        assert_eq!(fc_panels(7), (1, 3));
        assert_eq!(fc_panels(128), (32, 0));
    }

    #[test]
    fn padded_lanes_round_up_to_whole_panels() {
        assert_eq!(padded_lanes(4), 4);
        assert_eq!(padded_lanes(5), 8);
        assert_eq!(padded_lanes(128), 128);
        assert_eq!(padded_lanes(1), 4);
    }
}
