//! Pulse planning: compiled plan → incremental ("pulsed") execution over
//! a sliding window (the streaming subsystem's compiler half).
//!
//! The paper's flagship workload — always-on wake-word detection — slides
//! a spectrogram window one frame (one `H` row of the `[1,H,W,C]` input)
//! at a time and re-classifies. Re-running the full window per frame
//! re-pays every MAC for rows that were already processed. This pass
//! proves, per layer, which output rows of the previous verdict stay
//! valid when the window slides, and plans the minimal recompute:
//!
//! * The **streamable prefix**: the longest leading run of steps where a
//!   slide of the input by `delta_in` rows shifts the output by a
//!   computable `delta_out` rows and leaves every other row bit-identical.
//!   A geometry step (Conv2D / DepthwiseConv2D / AveragePool2D) qualifies
//!   iff it has no top padding and no bottom overhang in `H`
//!   (`pad_top == 0 && (out_h-1)*stride_h + k_h <= in_h`): then output row
//!   `oy` reads input rows `[oy*stride_h, oy*stride_h + k_h)`, so shifting
//!   the input by `stride_h` rows shifts the output by exactly one row.
//!   Pointwise steps (Relu / Relu6) shift trivially. Anything else
//!   (FullyConnected, Reshape, Softmax) mixes rows and ends the prefix.
//! * **Per-step state**: each geometry step keeps the trailing
//!   `state_rows = need_rows + underhang` rows of its *input*, where
//!   `need_rows = (delta_out-1)*stride_h + k_h` is what the incremental
//!   sub-kernel reads and `underhang = in_h - ((out_h-1)*stride_h + k_h)`
//!   is the bottom margin the full geometry never consumes. The sub-kernel
//!   reads state rows `[0, need_rows)` — the newest `underhang` rows only
//!   become visible after the next slide.
//! * The **carry**: the full output of the last prefix step, shifted by
//!   `carry_delta` rows per pulse and re-fed to the non-streamable tail,
//!   which runs full-window each pulse (it is where the model mixes the
//!   whole window anyway, and is typically the cheap part).
//! * The **cadence**: one pulse consumes `pulse_frames = Π stride_h`
//!   input rows (product over the prefix's geometry steps), so every
//!   per-step `delta` divides exactly and the carry advances by one row.
//!
//! Every plan self-certifies before it is returned: [`verify_pulse`]
//! re-derives the whole accounting from the [`CompiledModel`] and rejects
//! with the `V4xx` family on any mismatch, including `V405` — the pulsed
//! path must do *strictly less* kernel work than a full-window re-run.

use anyhow::{anyhow, bail, Result};

use super::plan::{CompiledModel, StepKind};
use super::verify::VerifyError;
use crate::kernels::view::ConvGeometry;
use crate::sim::cost::{microflow_step_macs, microflow_step_macs_rows};

/// How a prefix step participates in a pulse.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PulseStepKind {
    /// Spatial step (Conv2D / DepthwiseConv2D / AveragePool2D): owns a
    /// planned input-state region and re-runs a `delta_out`-row
    /// sub-geometry per pulse.
    Geo,
    /// Pointwise step (Relu / Relu6): stateless, transforms the delta
    /// rows in flight.
    Pointwise,
}

/// Per-step slice of the pulse plan (delay/overlap accounting).
#[derive(Clone, Copy, Debug)]
pub struct PulseStep {
    /// Index into `compiled.steps`.
    pub step: usize,
    pub kind: PulseStepKind,
    /// Rows entering / leaving this step per pulse.
    pub delta_in: usize,
    pub delta_out: usize,
    /// Elements per input / output row at this depth.
    pub in_row: usize,
    pub out_row: usize,
    /// Input rows the incremental sub-kernel reads (geo steps; 0 for
    /// pointwise).
    pub need_rows: usize,
    /// Input rows retained in this step's state region
    /// (`need_rows + underhang`; 0 for pointwise).
    pub state_rows: usize,
}

/// A certified pulse plan: everything the streaming executor needs, plus
/// the planned state-region accounting the verifier signs off on.
#[derive(Clone, Debug)]
pub struct PulsePlan {
    /// Sliding-window height (input `H`): frames needed before the first
    /// verdict.
    pub window_rows: usize,
    /// Elements per frame (input `W * C`).
    pub frame_len: usize,
    /// Input rows consumed per pulse (verdict cadence after warmup).
    pub pulse_frames: usize,
    /// Streamable prefix, one entry per step in `[0, tail_start)`.
    pub prefix: Vec<PulseStep>,
    /// First step of the non-streamable tail (ran full-window per pulse);
    /// `compiled.steps.len()` when the whole model streams.
    pub tail_start: usize,
    /// Carried activation (full output of the last prefix step): rows,
    /// elements per row, and rows appended per pulse.
    pub carry_rows: usize,
    pub carry_row: usize,
    pub carry_delta: usize,
    /// Planned ring-buffer bytes (the input window itself).
    pub ring_bytes: usize,
    /// Planned per-step state bytes (geo states + carry), disjoint from
    /// the ring.
    pub state_bytes: usize,
}

/// Geometry of the three spatial step kinds, if any.
fn step_geo(kind: &StepKind) -> Option<ConvGeometry> {
    match kind {
        StepKind::Conv2D { geo, .. }
        | StepKind::DepthwiseConv2D { geo, .. }
        | StepKind::AveragePool2D { geo, .. } => Some(*geo),
        _ => None,
    }
}

fn is_pointwise(kind: &StepKind) -> bool {
    matches!(kind, StepKind::Relu { .. } | StepKind::Relu6 { .. })
}

/// A geometry step shifts cleanly iff the window's top edge is real data
/// (no synthetic top padding rows that would stop being synthetic after a
/// slide) and the bottom row of the output consumes rows that exist
/// (shift-invariance of the row map `oy -> [oy*s, oy*s + k)`).
fn geo_streamable(g: &ConvGeometry) -> bool {
    g.pad_top == 0 && (g.out_h - 1) * g.stride_h + g.k_h <= g.in_h
}

impl PulsePlan {
    /// Derive (and self-certify) the pulse plan for a compiled model.
    ///
    /// Errors when the model has no streamable prefix (rank-≠3 input, a
    /// non-streamable first step, or a prefix whose incremental re-run
    /// would not beat the full window — `V405`).
    pub fn plan(compiled: &CompiledModel) -> Result<PulsePlan> {
        let shape = &compiled.input_shape;
        let [h, w, c] = shape[..] else {
            bail!("streaming needs a rank-3 [H,W,C] input, got {shape:?}");
        };
        let (window_rows, frame_len) = (h, w * c);
        if window_rows == 0 || frame_len == 0 {
            bail!("degenerate input shape {shape:?}");
        }

        // 1. Longest candidate prefix by shift-invariance classification,
        //    tracking the row structure through the chain.
        let mut classes: Vec<Option<ConvGeometry>> = Vec::new();
        let (mut rows, mut row) = (window_rows, frame_len);
        for step in &compiled.steps {
            if let Some(g) = step_geo(&step.kind) {
                // the row chain must line up with the planner's view of
                // the activation (guards against exotic reshapes upstream)
                if !(geo_streamable(&g) && g.in_h == rows && g.in_w * g.in_c == row) {
                    break;
                }
                rows = g.out_h;
                row = step.out_len / g.out_h;
                classes.push(Some(g));
            } else if is_pointwise(&step.kind) {
                classes.push(None);
            } else {
                break;
            }
        }

        // 2. Shrink until the delta chain is feasible: the pulse size is
        //    the product of the prefix's H-strides, and every step's
        //    delta must fit its geometry. Dropping the trailing geometry
        //    step shrinks the product, so this converges.
        let mut end = classes.len();
        let prefix = loop {
            if end == 0 || !classes[..end].iter().any(Option::is_some) {
                bail!("model has no streamable prefix (step 0 mixes rows or pads the top edge)");
            }
            let pulse_frames: usize =
                classes[..end].iter().flatten().map(|g| g.stride_h).product();
            match build_prefix(compiled, &classes[..end], window_rows, frame_len, pulse_frames) {
                Some(prefix) => break prefix,
                None => {
                    // drop the last geometry step and retry
                    end = classes[..end].iter().rposition(Option::is_some).unwrap();
                }
            }
        };

        let tail_start = prefix.len();
        let last = prefix.last().unwrap();
        let last_step = &compiled.steps[last.step];
        let (carry_row, carry_delta) = (last.out_row, last.delta_out);
        let carry_rows = last_step.out_len / carry_row;
        let state_bytes = prefix
            .iter()
            .map(|ps| ps.state_rows * ps.in_row)
            .sum::<usize>()
            + last_step.out_len;
        let pulse_frames = prefix[0].delta_in;

        let plan = PulsePlan {
            window_rows,
            frame_len,
            pulse_frames,
            prefix,
            tail_start,
            carry_rows,
            carry_row,
            carry_delta,
            ring_bytes: window_rows * frame_len,
            state_bytes,
        };
        verify_pulse(compiled, &plan)
            .map_err(|e| anyhow!("pulse plan failed certification: {e}"))?;
        Ok(plan)
    }

    /// MACs one pulse pays: `delta_out`-row sub-runs over the prefix plus
    /// a full-window tail re-run. Same cost basis as
    /// [`microflow_step_macs`] so the `V405` comparison is apples-to-apples.
    pub fn pulse_macs(&self, compiled: &CompiledModel) -> u64 {
        let prefix: u64 = self
            .prefix
            .iter()
            .map(|ps| {
                let step = &compiled.steps[ps.step];
                microflow_step_macs_rows(&step.kind, ps.delta_out, ps.delta_out * ps.out_row)
            })
            .sum();
        let tail: u64 = compiled.steps[self.tail_start..]
            .iter()
            .map(|s| microflow_step_macs(&s.kind, s.out_len))
            .sum();
        prefix + tail
    }

    /// MACs a full-window re-run pays (the one-shot baseline).
    pub fn full_macs(&self, compiled: &CompiledModel) -> u64 {
        compiled.steps.iter().map(|s| microflow_step_macs(&s.kind, s.out_len)).sum()
    }

    /// `pulse_macs / full_macs` — strictly below 1.0 for every certified
    /// plan (`V405`).
    pub fn savings_ratio(&self, compiled: &CompiledModel) -> f64 {
        self.pulse_macs(compiled) as f64 / self.full_macs(compiled) as f64
    }

    /// Total planned state region: ring + per-step states + carry.
    pub fn total_state_bytes(&self) -> usize {
        self.ring_bytes + self.state_bytes
    }
}

/// Forward delta-chain construction over a candidate prefix. `None` when
/// some step's delta exceeds its geometry (caller shrinks and retries).
fn build_prefix(
    compiled: &CompiledModel,
    classes: &[Option<ConvGeometry>],
    window_rows: usize,
    frame_len: usize,
    pulse_frames: usize,
) -> Option<Vec<PulseStep>> {
    if pulse_frames == 0 || pulse_frames > window_rows {
        return None;
    }
    let mut prefix = Vec::with_capacity(classes.len());
    let mut delta = pulse_frames;
    let mut row = frame_len;
    for (i, class) in classes.iter().enumerate() {
        let step = &compiled.steps[i];
        match class {
            Some(g) => {
                let delta_in = delta;
                // exact by construction: delta_in is the product of the
                // H-strides of this and every later geometry step
                let delta_out = delta_in / g.stride_h;
                if delta_in > g.in_h || delta_out > g.out_h {
                    return None;
                }
                let need_rows = (delta_out - 1) * g.stride_h + g.k_h;
                let underhang = g.in_h - ((g.out_h - 1) * g.stride_h + g.k_h);
                let out_row = step.out_len / g.out_h;
                prefix.push(PulseStep {
                    step: i,
                    kind: PulseStepKind::Geo,
                    delta_in,
                    delta_out,
                    in_row: row,
                    out_row,
                    need_rows,
                    state_rows: need_rows + underhang,
                });
                delta = delta_out;
                row = out_row;
            }
            None => prefix.push(PulseStep {
                step: i,
                kind: PulseStepKind::Pointwise,
                delta_in: delta,
                delta_out: delta,
                in_row: row,
                out_row: row,
                need_rows: 0,
                state_rows: 0,
            }),
        }
    }
    Some(prefix)
}

/// Static certification of a pulse plan against its compiled model: the
/// `V4xx` obligation family. Re-derives every quantity from the plan
/// steps and rejects on any mismatch, so a corrupted or hand-rolled
/// [`PulsePlan`] can never reach the streaming executor.
///
/// * `V401` — streamable-prefix classification unsound (padding /
///   overhang / row-chain misalignment / non-contiguous prefix)
/// * `V402` — pulse cadence broken (stride product, delta divisibility,
///   window bounds)
/// * `V403` — state-region sizing or disjoint accounting mismatch
/// * `V404` — state-shift / carry accounting broken
/// * `V405` — pulsed work not strictly less than a full-window re-run
pub fn verify_pulse(compiled: &CompiledModel, plan: &PulsePlan) -> Result<(), VerifyError> {
    let err = |code: &'static str, step: Option<usize>, msg: String| {
        Err(VerifyError::new(code, step, msg))
    };

    // ---- V401: prefix classification + row chain --------------------
    let [h, w, c] = compiled.input_shape[..] else {
        return err(
            "V401",
            None,
            format!("input shape {:?} is not rank-3 [H,W,C]", compiled.input_shape),
        );
    };
    if plan.window_rows != h || plan.frame_len != w * c {
        return err(
            "V401",
            None,
            format!(
                "window {}x{} disagrees with input [{h},{w},{c}]",
                plan.window_rows, plan.frame_len
            ),
        );
    }
    if plan.prefix.is_empty() || plan.tail_start != plan.prefix.len() {
        return err(
            "V401",
            None,
            format!("prefix len {} vs tail_start {}", plan.prefix.len(), plan.tail_start),
        );
    }
    if plan.tail_start > compiled.steps.len() {
        return err("V401", None, format!("tail_start {} beyond plan", plan.tail_start));
    }
    let (mut rows, mut row) = (plan.window_rows, plan.frame_len);
    let mut geo_seen = false;
    for (pos, ps) in plan.prefix.iter().enumerate() {
        if ps.step != pos {
            return err("V401", Some(pos), format!("prefix not contiguous at slot {pos}"));
        }
        let step = &compiled.steps[ps.step];
        match (step_geo(&step.kind), ps.kind) {
            (Some(g), PulseStepKind::Geo) => {
                if !geo_streamable(&g) {
                    return err(
                        "V401",
                        Some(pos),
                        format!(
                            "{} pads the top edge or overhangs the bottom (pad_top={}, \
                             rows {} of {})",
                            step.kind.name(),
                            g.pad_top,
                            (g.out_h - 1) * g.stride_h + g.k_h,
                            g.in_h
                        ),
                    );
                }
                if g.in_h != rows || g.in_w * g.in_c != row || ps.in_row != row {
                    return err(
                        "V401",
                        Some(pos),
                        format!(
                            "row chain misaligned: geometry {}x{} vs chained {rows}x{row}",
                            g.in_h,
                            g.in_w * g.in_c
                        ),
                    );
                }
                let out_row = step.out_len / g.out_h;
                if ps.out_row != out_row {
                    return err(
                        "V401",
                        Some(pos),
                        format!("out_row {} vs derived {out_row}", ps.out_row),
                    );
                }
                rows = g.out_h;
                row = out_row;
                geo_seen = true;
            }
            (None, PulseStepKind::Pointwise) if is_pointwise(&step.kind) => {
                if ps.in_row != row || ps.out_row != row {
                    return err("V401", Some(pos), "pointwise step changes row width".into());
                }
            }
            _ => {
                return err(
                    "V401",
                    Some(pos),
                    format!("{} misclassified as {:?}", step.kind.name(), ps.kind),
                );
            }
        }
    }
    if !geo_seen {
        return err("V401", None, "prefix has no geometry step (no recompute savings)".into());
    }

    // ---- V402: pulse cadence ----------------------------------------
    let stride_product: usize = plan
        .prefix
        .iter()
        .filter_map(|ps| step_geo(&compiled.steps[ps.step].kind).map(|g| g.stride_h))
        .product();
    if plan.pulse_frames != stride_product {
        return err(
            "V402",
            None,
            format!("pulse_frames {} != stride product {stride_product}", plan.pulse_frames),
        );
    }
    if plan.pulse_frames == 0 || plan.pulse_frames > plan.window_rows {
        return err(
            "V402",
            None,
            format!("pulse of {} frames outside window {}", plan.pulse_frames, plan.window_rows),
        );
    }
    let mut delta = plan.pulse_frames;
    for (pos, ps) in plan.prefix.iter().enumerate() {
        if ps.delta_in != delta {
            return err(
                "V402",
                Some(pos),
                format!("delta chain broken: delta_in {} vs carried {delta}", ps.delta_in),
            );
        }
        match step_geo(&compiled.steps[ps.step].kind) {
            Some(g) => {
                if ps.delta_in % g.stride_h != 0 || ps.delta_out != ps.delta_in / g.stride_h {
                    return err(
                        "V402",
                        Some(pos),
                        format!(
                            "delta {} does not divide by stride {} into {}",
                            ps.delta_in, g.stride_h, ps.delta_out
                        ),
                    );
                }
                if ps.delta_in > g.in_h || ps.delta_out > g.out_h {
                    return err(
                        "V402",
                        Some(pos),
                        format!(
                            "delta {}→{} exceeds geometry {}→{}",
                            ps.delta_in, ps.delta_out, g.in_h, g.out_h
                        ),
                    );
                }
            }
            None => {
                if ps.delta_out != ps.delta_in {
                    return err("V402", Some(pos), "pointwise step changes delta".into());
                }
            }
        }
        delta = ps.delta_out;
    }

    // ---- V403: state-region sizing + disjoint accounting ------------
    let mut state_sum = 0usize;
    for (pos, ps) in plan.prefix.iter().enumerate() {
        match step_geo(&compiled.steps[ps.step].kind) {
            Some(g) => {
                let need = (ps.delta_out - 1) * g.stride_h + g.k_h;
                let underhang = g.in_h - ((g.out_h - 1) * g.stride_h + g.k_h);
                if ps.need_rows != need {
                    return err(
                        "V403",
                        Some(pos),
                        format!("need_rows {} vs derived {need}", ps.need_rows),
                    );
                }
                if ps.state_rows != need + underhang || ps.state_rows > g.in_h {
                    return err(
                        "V403",
                        Some(pos),
                        format!(
                            "state_rows {} vs derived {} (in_h {})",
                            ps.state_rows,
                            need + underhang,
                            g.in_h
                        ),
                    );
                }
                state_sum += ps.state_rows * ps.in_row;
            }
            None => {
                if ps.state_rows != 0 || ps.need_rows != 0 {
                    return err("V403", Some(pos), "pointwise step claims state rows".into());
                }
            }
        }
    }
    let last = plan.prefix.last().unwrap();
    let carry_len = compiled.steps[last.step].out_len;
    state_sum += carry_len;
    if plan.state_bytes != state_sum {
        return err(
            "V403",
            None,
            format!(
                "state region accounting {} != sum of disjoint regions {state_sum}",
                plan.state_bytes
            ),
        );
    }
    if plan.ring_bytes != plan.window_rows * plan.frame_len {
        return err(
            "V403",
            None,
            format!(
                "ring bytes {} != window {}x{}",
                plan.ring_bytes, plan.window_rows, plan.frame_len
            ),
        );
    }

    // ---- V404: shift / carry accounting ------------------------------
    if plan.carry_row != last.out_row
        || plan.carry_rows * plan.carry_row != carry_len
        || plan.carry_delta != last.delta_out
        || plan.carry_delta > plan.carry_rows
    {
        return err(
            "V404",
            Some(last.step),
            format!(
                "carry {}x{} (+{}/pulse) disagrees with last prefix output len {carry_len} \
                 (delta_out {})",
                plan.carry_rows, plan.carry_row, plan.carry_delta, last.delta_out
            ),
        );
    }
    for (pos, ps) in plan.prefix.iter().enumerate() {
        if ps.kind == PulseStepKind::Geo && ps.state_rows == 0 {
            return err("V404", Some(pos), "geometry step with empty state cannot shift".into());
        }
    }

    // ---- V405: strict recompute savings ------------------------------
    let (pulse, full) = (plan.pulse_macs(compiled), plan.full_macs(compiled));
    if pulse >= full {
        return err(
            "V405",
            None,
            format!("pulsed work {pulse} MACs is not strictly below full-window {full} MACs"),
        );
    }

    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth;
    use crate::util::Prng;

    fn compiled(m: &crate::format::mfb::MfbModel) -> CompiledModel {
        CompiledModel::compile(m, Default::default()).unwrap()
    }

    fn stream_model(seed: u64) -> CompiledModel {
        compiled(&synth::stream_conv_chain(&mut Prng::new(seed), 2))
    }

    #[test]
    fn plans_certify_over_the_stream_zoo() {
        for (name, m) in synth::stream_zoo(20260731) {
            let c = compiled(&m);
            let p = PulsePlan::plan(&c).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert!(p.pulse_frames >= 1, "{name}");
            assert!(!p.prefix.is_empty(), "{name}");
            assert!(
                p.savings_ratio(&c) < 1.0,
                "{name}: ratio {}",
                p.savings_ratio(&c)
            );
            verify_pulse(&c, &p).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn delta_chain_ends_at_the_carry() {
        let c = stream_model(11);
        let p = PulsePlan::plan(&c).unwrap();
        assert_eq!(p.prefix[0].delta_in, p.pulse_frames);
        assert_eq!(p.prefix.last().unwrap().delta_out, p.carry_delta);
        for pair in p.prefix.windows(2) {
            assert_eq!(pair[0].delta_out, pair[1].delta_in);
        }
    }

    #[test]
    fn fc_models_have_no_streamable_prefix() {
        let c = compiled(&synth::random_fc_chain(&mut Prng::new(3), 2));
        let e = PulsePlan::plan(&c).unwrap_err().to_string();
        assert!(e.contains("rank-3"), "{e}");
    }

    #[test]
    fn no_savings_plan_is_rejected_with_v405() {
        // a conv whose kernel spans the whole window recomputes everything
        // every pulse: structurally consistent, zero savings
        let c = compiled(&synth::stream_full_height_conv(&mut Prng::new(5)));
        let e = PulsePlan::plan(&c).unwrap_err().to_string();
        assert!(e.contains("V405"), "{e}");
    }

    #[test]
    fn tampered_cadence_is_rejected_with_v402() {
        let c = stream_model(7);
        let mut p = PulsePlan::plan(&c).unwrap();
        p.pulse_frames += 1;
        let e = verify_pulse(&c, &p).unwrap_err();
        assert_eq!(e.code, "V402", "{e}");
    }

    #[test]
    fn tampered_state_rows_are_rejected_with_v403() {
        let c = stream_model(7);
        let mut p = PulsePlan::plan(&c).unwrap();
        let geo = p.prefix.iter().position(|ps| ps.kind == PulseStepKind::Geo).unwrap();
        p.prefix[geo].state_rows += 1;
        let e = verify_pulse(&c, &p).unwrap_err();
        assert_eq!(e.code, "V403", "{e}");
    }

    #[test]
    fn tampered_state_accounting_is_rejected_with_v403() {
        let c = stream_model(9);
        let mut p = PulsePlan::plan(&c).unwrap();
        p.state_bytes += 1;
        let e = verify_pulse(&c, &p).unwrap_err();
        assert_eq!(e.code, "V403", "{e}");
    }

    #[test]
    fn tampered_carry_is_rejected_with_v404() {
        let c = stream_model(13);
        let mut p = PulsePlan::plan(&c).unwrap();
        p.carry_rows += 1;
        let e = verify_pulse(&c, &p).unwrap_err();
        assert_eq!(e.code, "V404", "{e}");
    }

    #[test]
    fn misaligned_prefix_is_rejected_with_v401() {
        let c = stream_model(17);
        let mut p = PulsePlan::plan(&c).unwrap();
        p.prefix[0].step += 1;
        let e = verify_pulse(&c, &p).unwrap_err();
        assert_eq!(e.code, "V401", "{e}");
    }
}
