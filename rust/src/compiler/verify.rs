//! Compile-time model certification — static verification of compiled
//! plans (DESIGN.md S5; the safety story of the paper made checkable).
//!
//! The compiler pipeline (preprocess → pack → plan) is *assumed* correct
//! everywhere else in this crate; this pass proves the properties the
//! runtime relies on **by analysis, not by execution**, and attaches the
//! proof artifacts to the plan as a [`Certificate`]:
//!
//! 1. **Plan soundness** (`V1xx`) — steps chain
//!    (`out_len[i] == in_len[i+1]`, endpoints match the model signature),
//!    packed panel images match their `ConvGeometry`
//!    (`ceil(Cout/NR)*NR`-padded sizing with zero tail lanes, depthwise
//!    pre-transpose extents), page plans cover every FullyConnected row
//!    exactly once, and every step's scratch claim equals what its kernel
//!    actually stages.
//! 2. **Memory-plan soundness** (`V2xx`) — the ping-pong buffer schedule
//!    is replayed independently of [`MemoryPlan`] and every claim
//!    (`peak`, `peak_step`, per-step live sets, buffer and scratch sizes)
//!    is cross-checked. The replay itself is the disjointness proof: each
//!    non-Reshape step reads one ping-pong buffer and writes the other
//!    (with kernel scratch a third region), so input/output/scratch can
//!    never alias while live; the only in-place step, Reshape, is proven
//!    length-preserving.
//! 3. **Arithmetic soundness** (`V3xx`) — worst-case interval arithmetic
//!    over i8 inputs × the *actual* compile-time weights summed over K,
//!    proving every i32 accumulator (dot product, row/view sum, and each
//!    intermediate of the Eq. 4/7/10/13 epilogue
//!    `acc − z_W·Σx − w_zp_term[j] + kzxzw`) cannot overflow in any
//!    evaluation order, and that every folded [`PreComputed`] constant is
//!    finite and in representable range.
//!
//! Errors carry **stable codes** (see [`ERROR_CODE_TABLE`]); the decode
//! front door uses the matching `E4xx` family
//! ([`crate::format::error::DecodeError`]). `microflow audit` prints the
//! certificate report for a model.

use std::fmt;

use super::memory::StepMemory;
use super::pack::NR;
use super::paging::PagePlan;
use super::plan::{CompiledModel, Step, StepKind};
use crate::kernels::view::ConvGeometry;
use crate::tensor::quant::PreComputed;

/// Stable verification error codes, grouped by analysis pass. The decode
/// pass (`E4xx`) lives in [`crate::format::error`]; together the two
/// tables are the crate's complete machine-checkable failure vocabulary.
pub const ERROR_CODE_TABLE: &str = "\
V101  plan    broken step chain (step I/O lengths don't connect)
V102  plan    step shape/geometry inconsistent with its payload
V103  plan    FullyConnected weight payload length mismatch
V104  plan    packed Conv2D panel image malformed (sizing/tail lanes)
V105  plan    depthwise pre-transpose extents mismatch
V106  plan    page plan does not cover the paged FC rows exactly once
V107  plan    scratch claim differs from the kernel's staging need
V201  memory  peak RAM / peak step claim mismatch
V202  memory  per-step live-set claim mismatch
V203  memory  ping-pong buffer sizing mismatch (overlap possible)
V204  memory  shared kernel scratch sizing mismatch
V205  memory  in-place step is not length-preserving (aliasing)
V301  arith   i32 accumulator can overflow under worst-case i8 inputs
V302  arith   requantization multiplier non-finite or non-positive
V303  arith   folded bias constant non-finite
V304  arith   activation clamp bounds inverted
V305  arith   folded constant vectors sized unlike the output channels
V401  pulse   streamable-prefix classification unsound (padding/overhang/row chain)
V402  pulse   pulse cadence broken (stride product / delta divisibility / window)
V403  pulse   state-region sizing or disjoint accounting mismatch
V404  pulse   state-shift / carry accounting broken
V405  pulse   pulsed work not strictly less than a full-window re-run
E401  decode  bad magic or unsupported container version
E402  decode  truncated input
E403  decode  invalid UTF-8 in a string field
E404  decode  invalid count/length field (overflow or impossible)
E405  decode  tensor index out of range
E406  decode  trailing bytes after a complete structure
E407  decode  unknown enum code (opcode/dtype/padding/activation)
E408  decode  payload size disagrees with dims × dtype
";

/// A failed static-verification obligation: stable `code`, offending
/// `step` (when the obligation is per-step) and a human-readable message.
#[derive(Clone, Debug)]
pub struct VerifyError {
    pub code: &'static str,
    pub step: Option<usize>,
    pub msg: String,
}

impl VerifyError {
    pub(crate) fn new(code: &'static str, step: impl Into<Option<usize>>, msg: String) -> Self {
        VerifyError { code, step: step.into(), msg }
    }
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.step {
            Some(i) => write!(f, "{}: step #{i}: {}", self.code, self.msg),
            None => write!(f, "{}: {}", self.code, self.msg),
        }
    }
}

impl std::error::Error for VerifyError {}

/// Proven worst-case bound for one step's i32 accumulator chain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AccBound {
    pub lo: i64,
    pub hi: i64,
}

impl AccBound {
    const ZERO: AccBound = AccBound { lo: 0, hi: 0 };

    fn union(self, o: AccBound) -> AccBound {
        AccBound { lo: self.lo.min(o.lo), hi: self.hi.max(o.hi) }
    }

    fn max_abs(self) -> i64 {
        self.lo.unsigned_abs().max(self.hi.unsigned_abs()) as i64
    }

    fn fits_i32(self) -> bool {
        self.lo >= i32::MIN as i64 && self.hi <= i32::MAX as i64
    }

    /// Unused i32 magnitude bits above the proven bound (31 when the
    /// accumulator is identically zero).
    pub fn headroom_bits(self) -> u32 {
        let used = 64 - (self.max_abs() as u64).leading_zeros();
        31u32.saturating_sub(used)
    }
}

/// One step's certified facts.
#[derive(Clone, Debug)]
pub struct StepCert {
    pub op: &'static str,
    /// Live bytes while this step runs (input + output + scratch).
    pub live_bytes: usize,
    /// Worst-case accumulator interval (identically zero for
    /// non-accumulating steps).
    pub acc: AccBound,
}

/// The proof artifact attached to a certified [`CompiledModel`].
#[derive(Clone, Debug)]
pub struct Certificate {
    /// Independently recomputed RAM high-water mark (bytes).
    pub peak_ram: usize,
    /// Step index where the peak occurs.
    pub peak_step: usize,
    /// Bytes the executor allocates (ping-pong buffers + scratch).
    pub executor_bytes: usize,
    pub steps: Vec<StepCert>,
}

impl Certificate {
    /// Smallest accumulator headroom over all steps (31 for weightless
    /// models).
    pub fn min_headroom_bits(&self) -> u32 {
        self.steps.iter().map(|s| s.acc.headroom_bits()).min().unwrap_or(31)
    }
}

impl fmt::Display for Certificate {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "certified: {} steps, peak RAM {} B at step #{}, executor allocates {} B, \
             min accumulator headroom {} bits",
            self.steps.len(),
            self.peak_ram,
            self.peak_step,
            self.executor_bytes,
            self.min_headroom_bits()
        )?;
        writeln!(f, "  {:<5} {:<16} {:>8}  {:<28} {}", "step", "op", "live B", "accumulator range", "headroom")?;
        for (i, s) in self.steps.iter().enumerate() {
            writeln!(
                f,
                "  {:<5} {:<16} {:>8}  {:<28} {} bits",
                format!("#{i}"),
                s.op,
                s.live_bytes,
                format!("[{}, {}]", s.acc.lo, s.acc.hi),
                s.acc.headroom_bits()
            )?;
        }
        Ok(())
    }
}

/// Certify a compiled plan. Returns the [`Certificate`] or the first
/// failed obligation.
pub fn verify(m: &CompiledModel) -> Result<Certificate, VerifyError> {
    verify_plan(m)?;
    let (peak_ram, peak_step, executor_bytes, live) = verify_memory(m)?;
    let accs = verify_arithmetic(m)?;
    let steps = m
        .steps
        .iter()
        .zip(live)
        .zip(accs)
        .map(|((s, live_bytes), acc)| StepCert { op: s.kind.name(), live_bytes, acc })
        .collect();
    Ok(Certificate { peak_ram, peak_step, executor_bytes, steps })
}

fn prod(i: usize, what: &str, dims: &[usize]) -> Result<usize, VerifyError> {
    dims.iter().try_fold(1usize, |a, &b| a.checked_mul(b)).ok_or_else(|| {
        VerifyError::new("V102", i, format!("{what} element count overflows usize"))
    })
}

fn check_geometry(i: usize, geo: &ConvGeometry) -> Result<(), VerifyError> {
    let fields = [
        geo.in_h, geo.in_w, geo.in_c, geo.k_h, geo.k_w, geo.stride_h, geo.stride_w, geo.out_h,
        geo.out_w,
    ];
    if fields.contains(&0) {
        return Err(VerifyError::new("V102", i, format!("degenerate convolution geometry {geo:?}")));
    }
    Ok(())
}

fn check_io_lens(
    i: usize,
    s: &Step,
    want_in: usize,
    want_out: usize,
) -> Result<(), VerifyError> {
    if s.in_len != want_in || s.out_len != want_out {
        return Err(VerifyError::new(
            "V102",
            i,
            format!(
                "step I/O lengths ({}, {}) don't match the payload's ({want_in}, {want_out})",
                s.in_len, s.out_len
            ),
        ));
    }
    Ok(())
}

/// Pass 1: shape/plan soundness (`V1xx`).
fn verify_plan(m: &CompiledModel) -> Result<(), VerifyError> {
    let mut prev = m.input_len();
    for (i, s) in m.steps.iter().enumerate() {
        if s.in_len != prev {
            return Err(VerifyError::new(
                "V101",
                i,
                format!("input length {} != previous output length {prev}", s.in_len),
            ));
        }
        prev = s.out_len;

        match &s.kind {
            StepKind::FullyConnected { k, n, weights, .. } => {
                check_io_lens(i, s, *k, *n)?;
                let want = prod(i, "FC weights", &[*k, *n])?;
                if weights.len() != want {
                    return Err(VerifyError::new(
                        "V103",
                        i,
                        format!("FC weight payload {} elements, [K,N]=[{k},{n}] needs {want}", weights.len()),
                    ));
                }
            }
            StepKind::Conv2D { geo, filters, .. } => {
                check_geometry(i, geo)?;
                let in_len = prod(i, "conv input", &[geo.in_h, geo.in_w, geo.in_c])?;
                let out_len = prod(i, "conv output", &[geo.out_h, geo.out_w, filters.c_out])?;
                check_io_lens(i, s, in_len, out_len)?;
                let kkc = prod(i, "conv window", &[geo.k_h, geo.k_w, geo.in_c])?;
                if filters.c_out == 0 || filters.kkc != kkc {
                    return Err(VerifyError::new(
                        "V104",
                        i,
                        format!(
                            "panel image geometry (c_out {}, kkc {}) disagrees with the conv window {kkc}",
                            filters.c_out, filters.kkc
                        ),
                    ));
                }
                let want = prod(i, "panel image", &[filters.c_out.div_ceil(NR), NR, kkc])?;
                if filters.data.len() != want {
                    return Err(VerifyError::new(
                        "V104",
                        i,
                        format!(
                            "panel image {} bytes, ceil({}/{NR})*{NR}*{kkc} needs {want}",
                            filters.data.len(),
                            filters.c_out
                        ),
                    ));
                }
                // tail lanes past c_out are computed-but-dropped; they must
                // be zero so dropped lanes can never overflow differently
                // than certified real lanes
                let tail = filters.c_out % NR;
                if tail != 0 {
                    let panel = filters.panel(filters.panels() - 1);
                    for k in 0..kkc {
                        for r in tail..NR {
                            if panel[k * NR + r] != 0 {
                                return Err(VerifyError::new(
                                    "V104",
                                    i,
                                    format!("non-zero tail lane {r} at window offset {k}"),
                                ));
                            }
                        }
                    }
                }
            }
            StepKind::DepthwiseConv2D { geo, depth_multiplier, filters, .. } => {
                check_geometry(i, geo)?;
                if *depth_multiplier == 0 {
                    return Err(VerifyError::new("V102", i, "zero depth multiplier".into()));
                }
                let c_out = prod(i, "DW channels", &[geo.in_c, *depth_multiplier])?;
                let in_len = prod(i, "DW input", &[geo.in_h, geo.in_w, geo.in_c])?;
                let out_len = prod(i, "DW output", &[geo.out_h, geo.out_w, c_out])?;
                check_io_lens(i, s, in_len, out_len)?;
                let want = prod(i, "DW filters", &[geo.k_h, geo.k_w, c_out])?;
                if filters.len() != want {
                    return Err(VerifyError::new(
                        "V105",
                        i,
                        format!(
                            "pre-transposed DW filters {} elements, [Cout,KH*KW]=[{c_out},{}] needs {want}",
                            filters.len(),
                            geo.k_h * geo.k_w
                        ),
                    ));
                }
            }
            StepKind::AveragePool2D { geo, .. } => {
                check_geometry(i, geo)?;
                let in_len = prod(i, "pool input", &[geo.in_h, geo.in_w, geo.in_c])?;
                let out_len = prod(i, "pool output", &[geo.out_h, geo.out_w, geo.in_c])?;
                check_io_lens(i, s, in_len, out_len)?;
            }
            StepKind::Reshape => {} // length preservation is obligation V205
            StepKind::Softmax { .. } | StepKind::Relu { .. } | StepKind::Relu6 { .. } => {
                check_io_lens(i, s, s.in_len, s.in_len)?;
            }
        }

        let want_scratch = expected_scratch(s);
        if s.scratch_len != want_scratch {
            return Err(VerifyError::new(
                "V107",
                i,
                format!(
                    "{} claims {} scratch bytes, its kernel stages {want_scratch}",
                    s.kind.name(),
                    s.scratch_len
                ),
            ));
        }
    }
    if prev != m.output_len() {
        return Err(VerifyError::new(
            "V101",
            None,
            format!("plan ends with {prev} elements, model signature says {}", m.output_len()),
        ));
    }
    verify_page_plan(m)
}

/// What each kernel actually stages (the planner's scratch contract).
///
/// Kernel backends (`kernels::microkernel::backend`) do not change these
/// obligations: every backend — scalar, AVX2, NEON — consumes the same
/// `NR`-wide packed panels and staged views, keeps its accumulators in
/// registers, and finishes SIMD stride remainders in-kernel, so no
/// backend introduces widened-panel or realignment scratch. A future
/// backend that widens `NR` (or adds an MR input-row tile) must extend
/// this contract and the V104 packing checks above in the same PR — the
/// ROADMAP invariant that a new pass teaches the certifier its
/// obligations applies to kernel backends too.
fn expected_scratch(s: &Step) -> usize {
    match &s.kind {
        StepKind::FullyConnected { k, paged, .. } => {
            if *paged {
                *k
            } else {
                0
            }
        }
        StepKind::Conv2D { geo, .. } => {
            if geo.has_boundary() {
                geo.view_bytes()
            } else {
                0
            }
        }
        StepKind::DepthwiseConv2D { geo, .. } | StepKind::AveragePool2D { geo, .. } => {
            geo.view_bytes()
        }
        _ => 0,
    }
}

/// Page-plan coverage: paged FullyConnected steps must together account
/// for every output row exactly once, with the footprints the paper's
/// footnote-13 costing gives (`V106`).
fn verify_page_plan(m: &CompiledModel) -> Result<(), VerifyError> {
    let mut want: Option<PagePlan> = None;
    for (i, s) in m.steps.iter().enumerate() {
        if let StepKind::FullyConnected { k, n, paged, .. } = &s.kind {
            if *paged != m.options.paging {
                return Err(VerifyError::new(
                    "V106",
                    i,
                    format!("FC paged={paged} but the plan was compiled with paging={}", m.options.paging),
                ));
            }
            if *paged {
                let layer = PagePlan::for_fully_connected(*k, *n);
                want = Some(match want.take() {
                    Some(p) => p.merge(layer),
                    None => layer,
                });
            }
        }
    }
    match (&m.page_plan, want) {
        (None, None) => Ok(()),
        (Some(pp), Some(w)) if *pp == w => Ok(()),
        (Some(pp), Some(w)) => Err(VerifyError::new(
            "V106",
            None,
            format!("page plan {pp:?} does not cover the paged FC rows exactly once (recomputed {w:?})"),
        )),
        (Some(pp), None) => Err(VerifyError::new(
            "V106",
            None,
            format!("page plan {pp:?} present but no step is paged"),
        )),
        (None, Some(w)) => Err(VerifyError::new(
            "V106",
            None,
            format!("paged FC steps need a page plan covering {} rows, none attached", w.pages),
        )),
    }
}

/// Pass 2: memory-plan soundness (`V2xx`). Replays the ping-pong buffer
/// schedule independently of [`super::memory::MemoryPlan::analyze`] and
/// cross-checks every claim. Returns the recomputed
/// `(peak, peak_step, executor_bytes, per-step live bytes)`.
fn verify_memory(m: &CompiledModel) -> Result<(usize, usize, usize, Vec<usize>), VerifyError> {
    let mut per_step: Vec<StepMemory> = Vec::with_capacity(m.steps.len());
    let mut live = Vec::with_capacity(m.steps.len());
    let (mut peak, mut peak_step) = (0usize, 0usize);
    let (mut buf_a, mut buf_b, mut scratch) = (0usize, 0usize, 0usize);
    let mut reads_a = true;
    for (i, s) in m.steps.iter().enumerate() {
        let in_place = matches!(s.kind, StepKind::Reshape);
        if in_place && s.in_len != s.out_len {
            // the only in-place step: reinterpreting N elements as M != N
            // would read or expose bytes outside the live region
            return Err(VerifyError::new(
                "V205",
                i,
                format!("in-place Reshape changes element count {} -> {}", s.in_len, s.out_len),
            ));
        }
        let out_bytes = if in_place { 0 } else { s.out_len };
        let step_live = s
            .in_len
            .checked_add(out_bytes)
            .and_then(|v| v.checked_add(s.scratch_len))
            .ok_or_else(|| VerifyError::new("V202", i, "live set overflows usize".into()))?;
        if step_live > peak {
            peak = step_live;
            peak_step = i;
        }
        live.push(step_live);
        per_step.push(StepMemory {
            op: s.kind.name(),
            input: s.in_len,
            output: out_bytes,
            scratch: s.scratch_len,
        });
        if in_place {
            continue; // no flip: the live buffer is reinterpreted in place
        }
        // disjointness by construction: the reader and writer are distinct
        // buffers on every non-in-place step, scratch is a third region
        if reads_a {
            buf_a = buf_a.max(s.in_len);
            buf_b = buf_b.max(s.out_len);
        } else {
            buf_b = buf_b.max(s.in_len);
            buf_a = buf_a.max(s.out_len);
        }
        scratch = scratch.max(s.scratch_len);
        reads_a = !reads_a;
    }

    let mp = &m.memory;
    if let Some(i) = (0..per_step.len()).find(|&i| mp.per_step.get(i) != Some(&per_step[i])) {
        return Err(VerifyError::new(
            "V202",
            i,
            format!("claimed live set {:?}, recomputed {:?}", mp.per_step.get(i), per_step[i]),
        ));
    }
    if mp.per_step.len() != per_step.len() {
        return Err(VerifyError::new(
            "V202",
            None,
            format!("memory plan covers {} steps, the plan has {}", mp.per_step.len(), per_step.len()),
        ));
    }
    if mp.peak != peak || mp.peak_step != peak_step {
        return Err(VerifyError::new(
            "V201",
            None,
            format!(
                "claimed peak {} B at step #{}, recomputed {peak} B at step #{peak_step}",
                mp.peak, mp.peak_step
            ),
        ));
    }
    if mp.buf_a != buf_a || mp.buf_b != buf_b {
        return Err(VerifyError::new(
            "V203",
            None,
            format!(
                "claimed ping-pong buffers ({}, {}) B, the schedule needs ({buf_a}, {buf_b}) B",
                mp.buf_a, mp.buf_b
            ),
        ));
    }
    if mp.scratch != scratch {
        return Err(VerifyError::new(
            "V204",
            None,
            format!("claimed kernel scratch {} B, the steps need {scratch} B", mp.scratch),
        ));
    }
    Ok((peak, peak_step, buf_a + buf_b + scratch, live))
}

/// Pass 3: arithmetic soundness (`V3xx`).
fn verify_arithmetic(m: &CompiledModel) -> Result<Vec<AccBound>, VerifyError> {
    m.steps
        .iter()
        .enumerate()
        .map(|(i, s)| match &s.kind {
            StepKind::FullyConnected { k, n, weights, pc, .. } => {
                check_pc(i, pc, *n)?;
                epilogue_bounds(i, *k, pc, (0..*n).map(|j| (0..*k).map(move |r| weights[r * n + j])))
            }
            StepKind::Conv2D { geo, filters, pc, .. } => {
                check_pc(i, pc, filters.c_out)?;
                let kkc = geo.k_h * geo.k_w * geo.in_c;
                epilogue_bounds(
                    i,
                    kkc,
                    pc,
                    (0..filters.c_out)
                        .map(|co| (0..kkc).map(move |k| filters.panel(co / NR)[k * NR + co % NR])),
                )
            }
            StepKind::DepthwiseConv2D { geo, depth_multiplier, filters, pc, .. } => {
                let c_out = geo.in_c * depth_multiplier;
                let kk = geo.k_h * geo.k_w;
                check_pc(i, pc, c_out)?;
                epilogue_bounds(
                    i,
                    kk,
                    pc,
                    (0..c_out).map(|co| filters[co * kk..(co + 1) * kk].iter().copied()),
                )
            }
            StepKind::AveragePool2D { geo, ratio, act_min, act_max, .. } => {
                if !(ratio.is_finite() && *ratio > 0.0) {
                    return Err(VerifyError::new(
                        "V302",
                        i,
                        format!("pool requantization ratio {ratio} is not a positive finite value"),
                    ));
                }
                if act_min > act_max {
                    return Err(VerifyError::new(
                        "V304",
                        i,
                        format!("activation clamp [{act_min}, {act_max}] is inverted"),
                    ));
                }
                // window sum of kk int8 values
                let kk = (geo.k_h * geo.k_w) as i64;
                let acc = AccBound { lo: kk.saturating_mul(-128), hi: kk.saturating_mul(127) };
                if !acc.fits_i32() {
                    return Err(VerifyError::new(
                        "V301",
                        i,
                        format!("pool window sum bound [{}, {}] exceeds i32", acc.lo, acc.hi),
                    ));
                }
                Ok(acc)
            }
            StepKind::Softmax { s_x, s_y, .. }
            | StepKind::Relu { s_x, s_y, .. }
            | StepKind::Relu6 { s_x, s_y, .. } => {
                for (what, v) in [("input scale", *s_x), ("output scale", *s_y)] {
                    if !(v.is_finite() && v > 0.0) {
                        return Err(VerifyError::new(
                            "V302",
                            i,
                            format!("{what} {v} is not a positive finite value"),
                        ));
                    }
                }
                Ok(AccBound::ZERO)
            }
            StepKind::Reshape => Ok(AccBound::ZERO),
        })
        .collect()
}

fn check_pc(i: usize, pc: &PreComputed, c_out: usize) -> Result<(), VerifyError> {
    if pc.const_bias.len() != c_out || pc.w_zp_term.len() != c_out {
        return Err(VerifyError::new(
            "V305",
            i,
            format!(
                "folded constants sized ({}, {}) for {c_out} output channels",
                pc.const_bias.len(),
                pc.w_zp_term.len()
            ),
        ));
    }
    if !(pc.scale_ratio.is_finite() && pc.scale_ratio > 0.0) {
        return Err(VerifyError::new(
            "V302",
            i,
            format!("scale ratio {} is not a positive finite value", pc.scale_ratio),
        ));
    }
    if let Some(b) = pc.const_bias.iter().find(|b| !b.is_finite()) {
        return Err(VerifyError::new("V303", i, format!("folded bias constant {b} is not finite")));
    }
    if pc.act_min > pc.act_max {
        return Err(VerifyError::new(
            "V304",
            i,
            format!("activation clamp [{}, {}] is inverted", pc.act_min, pc.act_max),
        ));
    }
    Ok(())
}

/// Prove the full per-channel kernel expression
/// `acc − z_W·Σx − w_zp_term[j] + kzxzw` stays inside i32 for worst-case
/// i8 inputs, using the actual compile-time weights, in the kernels'
/// exact evaluation order (`V301`). `columns` yields each output
/// channel's K weights.
fn epilogue_bounds<C, W>(
    i: usize,
    k: usize,
    pc: &PreComputed,
    columns: C,
) -> Result<AccBound, VerifyError>
where
    C: Iterator<Item = W>,
    W: Iterator<Item = i8>,
{
    let overflow = |what: &str, b: AccBound| {
        VerifyError::new(
            "V301",
            i,
            format!("{what} bound [{}, {}] exceeds the i32 accumulator", b.lo, b.hi),
        )
    };
    // the data-dependent row/view sum: K int8 values summed in i32
    let xsum = AccBound {
        lo: (k as i64).saturating_mul(-128),
        hi: (k as i64).saturating_mul(127),
    };
    if !xsum.fits_i32() {
        return Err(overflow("input row sum", xsum));
    }
    // z_W · Σx, computed as an i32 product in the kernels
    let zw = pc.z_w as i64;
    let zw_xsum = AccBound {
        lo: (xsum.lo.saturating_mul(zw)).min(xsum.hi.saturating_mul(zw)),
        hi: (xsum.lo.saturating_mul(zw)).max(xsum.hi.saturating_mul(zw)),
    };
    if !zw_xsum.fits_i32() {
        return Err(overflow("z_W row-sum correction", zw_xsum));
    }

    let mut worst = AccBound::ZERO;
    for (j, col) in columns.enumerate() {
        let (mut lo, mut hi, mut abs) = (0i64, 0i64, 0i64);
        for w in col {
            let w = w as i64;
            let (a, b) = (w.saturating_mul(127), w.saturating_mul(-128));
            lo = lo.saturating_add(a.min(b));
            hi = hi.saturating_add(a.max(b));
            abs = abs.saturating_add(w.unsigned_abs() as i64 * 128);
        }
        // order-independence: every partial sum of the dot product is
        // bounded by Σ|w|·128, so any accumulation order stays in i32
        if abs > i32::MAX as i64 {
            return Err(overflow(&format!("channel {j} dot product (any order)"), AccBound { lo: -abs, hi: abs }));
        }
        let acc = AccBound { lo, hi };
        // the kernel epilogue, one i32 operation at a time
        let t1 = AccBound { lo: acc.lo.saturating_sub(zw_xsum.hi), hi: acc.hi.saturating_sub(zw_xsum.lo) };
        if !t1.fits_i32() {
            return Err(overflow(&format!("channel {j} acc − z_W·Σx"), t1));
        }
        let wz = pc.w_zp_term[j] as i64;
        let t2 = AccBound { lo: t1.lo.saturating_sub(wz), hi: t1.hi.saturating_sub(wz) };
        if !t2.fits_i32() {
            return Err(overflow(&format!("channel {j} after w_zp_term"), t2));
        }
        let kz = pc.kzxzw as i64;
        let t3 = AccBound { lo: t2.lo.saturating_add(kz), hi: t2.hi.saturating_add(kz) };
        if !t3.fits_i32() {
            return Err(overflow(&format!("channel {j} after kzxzw"), t3));
        }
        worst = worst.union(t3);
    }
    Ok(worst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::memory::MemoryPlan;
    use crate::compiler::plan::{CompileOptions, Step};
    use crate::format::mfb::MfbModel;
    use crate::tensor::quant::QParams;

    fn tiny_compiled(paging: bool) -> CompiledModel {
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        CompiledModel::compile(&m, CompileOptions { paging, certify: true }).unwrap()
    }

    /// A hand-built single-FC plan with chosen weights and constants.
    fn fc_plan(k: usize, n: usize, weights: Vec<i8>, w_zp_term: Vec<i32>, kzxzw: i32) -> CompiledModel {
        let pc = PreComputed {
            const_bias: vec![0.0; n],
            scale_ratio: 0.5,
            w_zp_term,
            kzxzw,
            z_w: 0,
            act_min: -128,
            act_max: 127,
        };
        let steps = vec![Step {
            kind: StepKind::FullyConnected { k, n, weights, pc, paged: false },
            in_len: k,
            out_len: n,
            scratch_len: 0,
        }];
        let memory = MemoryPlan::analyze(&steps);
        CompiledModel {
            steps,
            input_shape: vec![k],
            output_shape: vec![n],
            input_qparams: QParams::NONE,
            output_qparams: QParams::NONE,
            memory,
            page_plan: None,
            options: CompileOptions { paging: false, certify: true },
            certificate: None,
        }
    }

    #[test]
    fn certifies_the_tiny_model_and_reports() {
        let c = tiny_compiled(false);
        let cert = verify(&c).unwrap();
        assert_eq!(cert.steps.len(), 1);
        assert_eq!(cert.peak_ram, c.memory.peak);
        assert_eq!(cert.executor_bytes, c.memory.executor_bytes());
        assert!(cert.min_headroom_bits() > 10, "tiny FC has huge headroom");
        let report = cert.to_string();
        assert!(report.contains("FullyConnected") && report.contains("certified"), "{report}");
    }

    #[test]
    fn certifies_paged_plans() {
        let c = tiny_compiled(true);
        let cert = verify(&c).unwrap();
        assert_eq!(cert.steps[0].live_bytes, 2 + 3 + 2); // in + out + page scratch
    }

    #[test]
    fn broken_chain_is_v101() {
        let mut c = tiny_compiled(false);
        c.input_shape = vec![5];
        let e = verify(&c).unwrap_err();
        assert_eq!(e.code, "V101");
    }

    #[test]
    fn fc_weight_payload_mismatch_is_v103() {
        let mut c = tiny_compiled(false);
        if let StepKind::FullyConnected { weights, .. } = &mut c.steps[0].kind {
            weights.pop();
        }
        assert_eq!(verify(&c).unwrap_err().code, "V103");
    }

    #[test]
    fn overflow_capable_fc_is_v301() {
        // K = 140_000 saturated weights: Σ|w|·128 = 140_000·127·128 ≈ 2.3e9
        // exceeds i32::MAX ≈ 2.1e9, so some accumulation order overflows
        let k = 140_000;
        let c = fc_plan(k, 1, vec![127; k], vec![0], 0);
        let e = verify(&c).unwrap_err();
        assert_eq!(e.code, "V301");
        assert!(e.to_string().contains("V301"), "{e}");
    }

    #[test]
    fn epilogue_constant_overflow_is_v301() {
        // tiny dot product, but the folded w_zp_term shifts it past i32
        let c = fc_plan(2, 1, vec![1, 1], vec![i32::MIN], 0);
        assert_eq!(verify(&c).unwrap_err().code, "V301");
    }

    #[test]
    fn safe_fc_certifies_with_exact_interval() {
        let c = fc_plan(2, 1, vec![3, -2], vec![7], -1);
        let cert = verify(&c).unwrap();
        // col interval: 3·[-128,127] + (-2)·[-128,127] = [-384+(-254), 381+256]
        //             = [-638, 637]; then −7 then −1
        assert_eq!(cert.steps[0].acc, AccBound { lo: -638 - 7 - 1, hi: 637 - 7 - 1 });
    }

    #[test]
    fn lying_peak_is_v201() {
        let mut c = tiny_compiled(false);
        c.memory.peak += 1;
        assert_eq!(verify(&c).unwrap_err().code, "V201");
    }

    #[test]
    fn lying_live_set_is_v202() {
        let mut c = tiny_compiled(false);
        c.memory.per_step[0].input += 1;
        assert_eq!(verify(&c).unwrap_err().code, "V202");
    }

    #[test]
    fn undersized_ping_pong_buffer_is_v203() {
        let mut c = tiny_compiled(false);
        c.memory.buf_a -= 1;
        assert_eq!(verify(&c).unwrap_err().code, "V203");
    }

    #[test]
    fn undersized_scratch_is_v204() {
        let mut c = tiny_compiled(true);
        c.memory.scratch -= 1;
        assert_eq!(verify(&c).unwrap_err().code, "V204");
    }

    #[test]
    fn non_length_preserving_reshape_is_v205() {
        let mut c = tiny_compiled(false);
        // splice an in-place step that shrinks the buffer: 3 -> 2 elements
        c.steps.push(Step { kind: StepKind::Reshape, in_len: 3, out_len: 2, scratch_len: 0 });
        c.output_shape = vec![2];
        c.memory = MemoryPlan::analyze(&c.steps);
        assert_eq!(verify(&c).unwrap_err().code, "V205");
    }

    #[test]
    fn bad_panel_sizing_is_v104() {
        let m = crate::synth::random_conv(&mut crate::util::Prng::new(11));
        let mut c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
        if let StepKind::Conv2D { filters, .. } = &mut c.steps[0].kind {
            filters.data.pop();
        }
        assert_eq!(verify(&c).unwrap_err().code, "V104");
    }

    #[test]
    fn nonzero_tail_lane_is_v104() {
        // find a seeded conv whose c_out is not a multiple of NR
        let mut rng = crate::util::Prng::new(3);
        let c = loop {
            let m = crate::synth::random_conv(&mut rng);
            let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
            let StepKind::Conv2D { filters, .. } = &c.steps[0].kind else { unreachable!() };
            if filters.c_out % NR != 0 {
                break c;
            }
        };
        let mut c = c;
        if let StepKind::Conv2D { filters, .. } = &mut c.steps[0].kind {
            let last = filters.data.len() - 1; // lane NR-1 of the last window slot
            filters.data[last] = 1;
        }
        assert_eq!(verify(&c).unwrap_err().code, "V104");
    }

    #[test]
    fn page_plan_coverage_lies_are_v106() {
        let mut c = tiny_compiled(true);
        if let Some(pp) = &mut c.page_plan {
            pp.pages += 1; // claims one more page than FC rows
        }
        assert_eq!(verify(&c).unwrap_err().code, "V106");
        let mut c = tiny_compiled(true);
        c.page_plan = None;
        assert_eq!(verify(&c).unwrap_err().code, "V106");
    }

    #[test]
    fn scratch_claim_mismatch_is_v107() {
        let mut c = tiny_compiled(false);
        c.steps[0].scratch_len = 99;
        c.memory = MemoryPlan::analyze(&c.steps);
        assert_eq!(verify(&c).unwrap_err().code, "V107");
    }

    #[test]
    fn bad_scale_ratio_is_v302_and_nan_bias_v303() {
        let mut c = fc_plan(2, 1, vec![1, 1], vec![0], 0);
        if let StepKind::FullyConnected { pc, .. } = &mut c.steps[0].kind {
            pc.scale_ratio = f32::NAN;
        }
        assert_eq!(verify(&c).unwrap_err().code, "V302");
        let mut c = fc_plan(2, 1, vec![1, 1], vec![0], 0);
        if let StepKind::FullyConnected { pc, .. } = &mut c.steps[0].kind {
            pc.const_bias[0] = f32::INFINITY;
        }
        assert_eq!(verify(&c).unwrap_err().code, "V303");
    }

    #[test]
    fn inverted_clamp_is_v304_and_wrong_pc_len_v305() {
        let mut c = fc_plan(2, 1, vec![1, 1], vec![0], 0);
        if let StepKind::FullyConnected { pc, .. } = &mut c.steps[0].kind {
            pc.act_min = 10;
            pc.act_max = -10;
        }
        assert_eq!(verify(&c).unwrap_err().code, "V304");
        let mut c = fc_plan(2, 1, vec![1, 1], vec![0], 0);
        if let StepKind::FullyConnected { pc, .. } = &mut c.steps[0].kind {
            pc.w_zp_term.push(0);
        }
        assert_eq!(verify(&c).unwrap_err().code, "V305");
    }

    #[test]
    fn synth_zoo_certifies_across_paging_modes() {
        let mut rng = crate::util::Prng::new(1234);
        for _ in 0..4 {
            let m = crate::synth::random_fc_chain(&mut rng, 3);
            for paging in [false, true] {
                let c = CompiledModel::compile(&m, CompileOptions { paging, certify: true }).unwrap();
                let cert = c.certificate.as_ref().expect("certified by default");
                assert_eq!(cert.peak_ram, c.memory.peak);
            }
        }
        for _ in 0..4 {
            let m = crate::synth::random_conv(&mut rng);
            let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
            assert!(c.certificate.is_some());
        }
    }

    #[test]
    fn headroom_bits_are_sane() {
        assert_eq!(AccBound::ZERO.headroom_bits(), 31);
        assert_eq!(AccBound { lo: -1, hi: 1 }.headroom_bits(), 30);
        assert_eq!(AccBound { lo: 0, hi: i32::MAX as i64 }.headroom_bits(), 0);
    }

    #[test]
    fn error_code_table_covers_every_family() {
        for code in ["V101", "V107", "V201", "V205", "V301", "V305", "V401", "V405", "E401", "E408"] {
            assert!(ERROR_CODE_TABLE.contains(code), "{code} missing from table");
        }
    }
}
