//! Execution planning: MFB internal representation → static plan
//! (paper Sec. 3.3; DESIGN.md S5).
//!
//! A [`CompiledModel`] is the runtime image the paper's proc-macro would
//! have generated: a linear sequence of [`Step`]s with
//!
//! * all tensor shapes resolved (the const-generics of the paper),
//! * all Eq. 4/7/10/13 constants folded ([`PreComputed`]),
//! * weight payloads **packed** into kernel layout by [`super::pack`]
//!   (Conv2D filters as `NR`-wide output-channel panels, depthwise
//!   filters pre-transposed — never at inference time),
//! * every name / version / option byte dropped,
//! * a [`MemoryPlan`] giving the static buffer sizes.
//!
//! Single-path graphs only (the paper's models are chains); the parser
//! validates that each operator consumes the previous operator's output.

use anyhow::{bail, Context, Result};

use super::memory::MemoryPlan;
use super::pack;
use super::paging::PagePlan;
use super::preprocess;
use super::verify::Certificate;
use crate::format::mfb::{MfbModel, OpCode, OpOptions, Padding};
use crate::kernels::microkernel::PackedConvFilters;
use crate::kernels::view::ConvGeometry;
use crate::tensor::quant::{PreComputed, QParams};

/// Compilation options.
#[derive(Clone, Copy, Debug)]
pub struct CompileOptions {
    /// Execute FullyConnected layers page-by-page (paper Sec. 4.3). Slower
    /// but shrinks the working set to one page (for 2 kB-RAM devices).
    pub paging: bool,
    /// Run the static certifier ([`super::verify`]) on the finished plan
    /// and attach the [`Certificate`]. On by default; opting out skips the
    /// analysis but leaves the plan otherwise identical.
    pub certify: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions { paging: false, certify: true }
    }
}

/// One executable step.
#[derive(Clone, Debug)]
pub struct Step {
    pub kind: StepKind,
    /// Input / output activation element counts (per sample).
    pub in_len: usize,
    pub out_len: usize,
    /// Scratch bytes the kernel needs (view buffer / page buffer).
    pub scratch_len: usize,
}

/// Step payload: everything the kernel call needs, nothing else.
#[derive(Clone, Debug)]
pub enum StepKind {
    FullyConnected {
        k: usize,
        n: usize,
        weights: Vec<i8>,
        pc: PreComputed,
        paged: bool,
    },
    Conv2D {
        geo: ConvGeometry,
        /// Compile-time packed panel image ([`pack::pack_conv2d`]); also
        /// the single source of truth for `Cout`.
        filters: PackedConvFilters,
        z_x: i8,
        pc: PreComputed,
    },
    DepthwiseConv2D {
        geo: ConvGeometry,
        depth_multiplier: usize,
        /// Pre-transposed to `[Cout, KH*KW]` ([`pack::pack_depthwise`]).
        filters: Vec<i8>,
        z_x: i8,
        pc: PreComputed,
    },
    AveragePool2D {
        geo: ConvGeometry,
        z_x: i8,
        ratio: f32,
        z_y: i32,
        act_min: i8,
        act_max: i8,
    },
    /// Pure re-interpretation of the buffer; no data movement at runtime.
    Reshape,
    Softmax {
        s_x: f32,
        z_x: i32,
        s_y: f32,
        z_y: i32,
    },
    Relu {
        s_x: f32,
        z_x: i32,
        s_y: f32,
        z_y: i32,
    },
    Relu6 {
        s_x: f32,
        z_x: i32,
        s_y: f32,
        z_y: i32,
    },
}

impl StepKind {
    pub fn name(&self) -> &'static str {
        match self {
            StepKind::FullyConnected { .. } => "FullyConnected",
            StepKind::Conv2D { .. } => "Conv2D",
            StepKind::DepthwiseConv2D { .. } => "DepthwiseConv2D",
            StepKind::AveragePool2D { .. } => "AveragePool2D",
            StepKind::Reshape => "Reshape",
            StepKind::Softmax { .. } => "Softmax",
            StepKind::Relu { .. } => "Relu",
            StepKind::Relu6 { .. } => "Relu6",
        }
    }

    /// Multiply-accumulate count per inference (the sim cost driver).
    pub fn macs(&self, out_len: usize) -> u64 {
        match self {
            StepKind::FullyConnected { k, n, .. } => (*k as u64) * (*n as u64),
            StepKind::Conv2D { geo, filters, .. } => {
                (geo.out_h * geo.out_w * filters.c_out * geo.k_h * geo.k_w * geo.in_c) as u64
            }
            StepKind::DepthwiseConv2D { geo, depth_multiplier, .. } => {
                (geo.out_h * geo.out_w * geo.in_c * depth_multiplier * geo.k_h * geo.k_w) as u64
            }
            StepKind::AveragePool2D { geo, .. } => {
                (geo.out_h * geo.out_w * geo.in_c * geo.k_h * geo.k_w) as u64
            }
            StepKind::Softmax { .. } | StepKind::Relu { .. } | StepKind::Relu6 { .. } => {
                out_len as u64
            }
            StepKind::Reshape => 0,
        }
    }

    /// Weight bytes carried by this step (Flash cost). Conv2D counts the
    /// packed panel image — zero-filled tail lanes ship in Flash too.
    pub fn weight_bytes(&self) -> usize {
        match self {
            StepKind::FullyConnected { weights, pc, .. } => weights.len() + pc.const_bias.len() * 4,
            StepKind::Conv2D { filters, pc, .. } => {
                filters.flash_bytes() + pc.const_bias.len() * 4
            }
            StepKind::DepthwiseConv2D { filters, pc, .. } => {
                filters.len() + pc.const_bias.len() * 4
            }
            _ => 0,
        }
    }
}

/// A compiled model: the MicroFlow Runtime's entire world.
#[derive(Clone, Debug)]
pub struct CompiledModel {
    pub steps: Vec<Step>,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub input_qparams: QParams,
    pub output_qparams: QParams,
    pub memory: MemoryPlan,
    pub page_plan: Option<PagePlan>,
    pub options: CompileOptions,
    /// Proof artifact from the static certifier; `Some` whenever the plan
    /// was compiled with `options.certify` (the default).
    pub certificate: Option<Certificate>,
}

impl CompiledModel {
    /// Run the full compiler pipeline on a parsed model.
    pub fn compile(model: &MfbModel, options: CompileOptions) -> Result<CompiledModel> {
        if model.graph_inputs.len() != 1 || model.graph_outputs.len() != 1 {
            bail!("only single-input single-output graphs are supported");
        }
        let mut steps = Vec::with_capacity(model.operators.len());
        let mut cur_tensor = model.graph_inputs[0];
        let mut page_plan: Option<PagePlan> = None;
        let tensor = |idx: usize| {
            model.tensors.get(idx).ok_or_else(|| anyhow::anyhow!("tensor index {idx} out of range"))
        };

        for (oi, op) in model.operators.iter().enumerate() {
            let (want_in, _) = preprocess::expected_arity(op.opcode);
            if op.inputs.len() != want_in {
                bail!("op #{oi} {}: expected {want_in} inputs, got {}", op.opcode.name(), op.inputs.len());
            }
            let x_idx = op.input(0)?;
            if x_idx != cur_tensor {
                bail!(
                    "op #{oi} {}: non-chain graph (input tensor {x_idx}, expected {cur_tensor})",
                    op.opcode.name()
                );
            }
            let x_t = tensor(x_idx)?;
            let y_idx = op.output(0)?;
            let y_t = tensor(y_idx)?;
            let in_len = checked_numel(oi, &x_t.dims)?;
            let out_len = checked_numel(oi, &y_t.dims)?;
            let act = preprocess::fused_act_of(op)?;

            let (kind, scratch_len) = match op.opcode {
                OpCode::FullyConnected => {
                    let w_t = tensor(op.input(1)?)?;
                    let b_t = tensor(op.input(2)?)?;
                    let pc = preprocess::preprocess_fully_connected(x_t, w_t, b_t, y_t, act)
                        .with_context(|| format!("op #{oi}"))?;
                    let (k, n) = (w_t.dims[0], w_t.dims[1]);
                    if in_len != k || out_len != n {
                        bail!("op #{oi} FC: shape mismatch in={in_len} k={k} out={out_len} n={n}");
                    }
                    let paged = options.paging;
                    if paged {
                        let plan = PagePlan::for_fully_connected(k, n);
                        page_plan = Some(match page_plan.take() {
                            Some(p) => p.merge(plan),
                            None => plan,
                        });
                    }
                    let scratch = if paged { k } else { 0 };
                    (
                        StepKind::FullyConnected { k, n, weights: w_t.data_i8()?, pc, paged },
                        scratch,
                    )
                }
                OpCode::Conv2D => {
                    let f_t = tensor(op.input(1)?)?;
                    let b_t = tensor(op.input(2)?)?;
                    let (stride, padding) = match op.options {
                        OpOptions::Conv2D { stride, padding, .. } => (stride, padding),
                        _ => bail!("op #{oi}: bad Conv2D options"),
                    };
                    let [c_out, kh, kw, c_in] = f_t.dims[..] else {
                        bail!("op #{oi}: Conv2D filters must be 4-D");
                    };
                    let [_, h, w, ci2] = x_t.dims[..] else {
                        bail!("op #{oi}: Conv2D input must be [1,H,W,C]");
                    };
                    if ci2 != c_in {
                        bail!("op #{oi}: Conv2D Cin mismatch {ci2} vs {c_in}");
                    }
                    let geo = ConvGeometry::new(h, w, c_in, kh, kw, stride.0, stride.1, padding)
                        .with_context(|| format!("op #{oi} Conv2D"))?;
                    check_out_dims(oi, &y_t.dims, geo.out_h, geo.out_w, c_out)?;
                    let pc = preprocess::preprocess_conv2d(x_t, f_t, b_t, y_t, act)?;
                    // view scratch is only staged for boundary positions;
                    // an all-interior conv (every VALID layer) borrows its
                    // rows from the input and needs none
                    let scratch =
                        if geo.has_boundary() { geo.k_h * geo.k_w * geo.in_c } else { 0 };
                    // compile-time weight packing: [Cout, KH*KW*Cin] ->
                    // NR-wide output-channel panels for the register-tiled
                    // kernel core (bit-identical by the pack contract)
                    let filters = pack::pack_conv2d(&f_t.data_i8()?, c_out, kh * kw * c_in);
                    (
                        StepKind::Conv2D {
                            geo,
                            filters,
                            z_x: zp_i8(oi, x_t.qparams.zero_point)?,
                            pc,
                        },
                        scratch,
                    )
                }
                OpCode::DepthwiseConv2D => {
                    let w_t = tensor(op.input(1)?)?;
                    let b_t = tensor(op.input(2)?)?;
                    let (stride, padding, mult) = match op.options {
                        OpOptions::DepthwiseConv2D { stride, padding, depth_multiplier, .. } => {
                            (stride, padding, depth_multiplier)
                        }
                        _ => bail!("op #{oi}: bad DepthwiseConv2D options"),
                    };
                    let [_, kh, kw, c_out] = w_t.dims[..] else {
                        bail!("op #{oi}: DW filters must be [1,KH,KW,Cout]");
                    };
                    let [_, h, w, c_in] = x_t.dims[..] else {
                        bail!("op #{oi}: DW input must be [1,H,W,C]");
                    };
                    if c_out != c_in * mult {
                        bail!("op #{oi}: DW Cout {c_out} != Cin {c_in} * mult {mult}");
                    }
                    let geo = ConvGeometry::new(h, w, c_in, kh, kw, stride.0, stride.1, padding)
                        .with_context(|| format!("op #{oi} DepthwiseConv2D"))?;
                    check_out_dims(oi, &y_t.dims, geo.out_h, geo.out_w, c_out)?;
                    let pc = preprocess::preprocess_depthwise(x_t, w_t, b_t, y_t, act)?;
                    let scratch = geo.k_h * geo.k_w * geo.in_c;
                    // compile-time weight re-layout: [KH*KW, Cout] ->
                    // [Cout, KH*KW] so the per-channel kernel streams its
                    // filter contiguously (EXPERIMENTS.md §Perf)
                    let filters = pack::pack_depthwise(&w_t.data_i8()?, kh * kw, c_out);
                    (
                        StepKind::DepthwiseConv2D {
                            geo,
                            depth_multiplier: mult,
                            filters,
                            z_x: zp_i8(oi, x_t.qparams.zero_point)?,
                            pc,
                        },
                        scratch,
                    )
                }
                OpCode::AveragePool2D => {
                    let (filter, stride, padding) = match op.options {
                        OpOptions::AveragePool2D { filter, stride, padding, .. } => {
                            (filter, stride, padding)
                        }
                        _ => bail!("op #{oi}: bad AveragePool2D options"),
                    };
                    let [_, h, w, c] = x_t.dims[..] else {
                        bail!("op #{oi}: pool input must be [1,H,W,C]");
                    };
                    let geo = ConvGeometry::new(h, w, c, filter.0, filter.1, stride.0, stride.1, padding)
                        .with_context(|| format!("op #{oi} AveragePool2D"))?;
                    check_out_dims(oi, &y_t.dims, geo.out_h, geo.out_w, c)?;
                    if padding == Padding::Same && (h % stride.0 != 0 || w % stride.1 != 0) {
                        // the Eq. 13 constant 1/(mn) assumes full windows
                        bail!("op #{oi}: SAME-padded AveragePool2D with partial windows unsupported");
                    }
                    let ratio = x_t.qparams.scale / y_t.qparams.scale;
                    let (act_min, act_max) = act.bounds(y_t.qparams.scale, y_t.qparams.zero_point);
                    let scratch = geo.k_h * geo.k_w * geo.in_c;
                    (
                        StepKind::AveragePool2D {
                            geo,
                            z_x: zp_i8(oi, x_t.qparams.zero_point)?,
                            ratio,
                            z_y: y_t.qparams.zero_point,
                            act_min,
                            act_max,
                        },
                        scratch,
                    )
                }
                OpCode::Reshape => {
                    if in_len != out_len {
                        bail!("op #{oi}: reshape changes element count {in_len} -> {out_len}");
                    }
                    (StepKind::Reshape, 0)
                }
                OpCode::Softmax => (
                    StepKind::Softmax {
                        s_x: x_t.qparams.scale,
                        z_x: x_t.qparams.zero_point,
                        s_y: y_t.qparams.scale,
                        z_y: y_t.qparams.zero_point,
                    },
                    0,
                ),
                OpCode::Relu => (
                    StepKind::Relu {
                        s_x: x_t.qparams.scale,
                        z_x: x_t.qparams.zero_point,
                        s_y: y_t.qparams.scale,
                        z_y: y_t.qparams.zero_point,
                    },
                    0,
                ),
                OpCode::Relu6 => (
                    StepKind::Relu6 {
                        s_x: x_t.qparams.scale,
                        z_x: x_t.qparams.zero_point,
                        s_y: y_t.qparams.scale,
                        z_y: y_t.qparams.zero_point,
                    },
                    0,
                ),
            };
            steps.push(Step { kind, in_len, out_len, scratch_len });
            cur_tensor = y_idx;
        }
        if cur_tensor != model.graph_outputs[0] {
            bail!("graph output {} is not the last operator's output {cur_tensor}", model.graph_outputs[0]);
        }

        let memory = MemoryPlan::analyze(&steps);
        let mut compiled = CompiledModel {
            steps,
            input_shape: model.input_shape(),
            output_shape: model.output_shape(),
            input_qparams: model.input_qparams(),
            output_qparams: model.output_qparams(),
            memory,
            page_plan,
            options,
            certificate: None,
        };
        if options.certify {
            compiled.certificate =
                Some(super::verify::verify(&compiled).context("plan failed certification")?);
        }
        Ok(compiled)
    }

    /// Per-sample input element count.
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }

    /// Total MACs per inference (cost-model driver).
    pub fn total_macs(&self) -> u64 {
        self.steps.iter().map(|s| s.kind.macs(s.out_len)).sum()
    }

    /// Total weight + folded-constant bytes (the Flash payload).
    pub fn weight_bytes(&self) -> usize {
        self.steps.iter().map(|s| s.kind.weight_bytes()).sum()
    }
}

fn check_out_dims(oi: usize, dims: &[usize], oh: usize, ow: usize, c: usize) -> Result<()> {
    if dims != [1, oh, ow, c] {
        bail!("op #{oi}: output dims {:?} don't match computed [1,{oh},{ow},{c}]", dims);
    }
    Ok(())
}

/// Element count with overflow surfaced as a compile error instead of a
/// debug panic / release wraparound.
fn checked_numel(oi: usize, dims: &[usize]) -> Result<usize> {
    dims.iter()
        .try_fold(1usize, |a, &b| a.checked_mul(b))
        .with_context(|| format!("op #{oi}: tensor element count overflows usize ({dims:?})"))
}

/// Checked i32 → i8 zero-point narrowing (int8 tensors must carry an
/// in-range zero point; a hostile container can claim otherwise).
fn zp_i8(oi: usize, zp: i32) -> Result<i8> {
    i8::try_from(zp).map_err(|_| anyhow::anyhow!("op #{oi}: int8 zero point {zp} out of range"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::mfb::MfbModel;

    // reuse the hand-built tiny model from the format tests via a local copy
    fn tiny() -> MfbModel {
        MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap()
    }

    #[test]
    fn compiles_tiny_fc_chain() {
        let m = tiny();
        let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
        assert_eq!(c.steps.len(), 1);
        assert_eq!(c.input_len(), 2);
        assert_eq!(c.output_len(), 3);
        assert_eq!(c.total_macs(), 6);
        match &c.steps[0].kind {
            StepKind::FullyConnected { k, n, pc, paged, .. } => {
                assert_eq!((*k, *n), (2, 3));
                assert!(!paged);
                // fused relu bounds: act_min == z_y == 0
                assert_eq!(pc.act_min, 0);
            }
            other => panic!("unexpected step {other:?}"),
        }
    }

    #[test]
    fn paging_option_creates_page_plan() {
        let m = tiny();
        let c = CompiledModel::compile(&m, CompileOptions { paging: true, ..Default::default() }).unwrap();
        let pp = c.page_plan.expect("page plan");
        assert_eq!(pp.pages, 3); // one per output neuron
        assert!(c.steps[0].scratch_len > 0);
    }

    #[test]
    fn rejects_non_chain_graph() {
        let mut m = tiny();
        // corrupt: make the op consume tensor 1 (weights) as activation
        m.operators[0].inputs[0] = 1;
        assert!(CompiledModel::compile(&m, CompileOptions::default()).is_err());
    }

    #[test]
    fn rejects_wrong_graph_output() {
        let mut m = tiny();
        m.graph_outputs[0] = 0;
        assert!(CompiledModel::compile(&m, CompileOptions::default()).is_err());
    }
}
