//! Pre-processing: fold every input-independent term of the quantized
//! operator formulas into constants (paper Sec. 3.3.3; Eq. 4/7/10/13).
//!
//! For each weighted operator this computes, offline:
//!
//! * `const_bias[j] = z_Y + (s_b/s_Y)(b_q[j] - z_b)`   (float32)
//! * `scale_ratio  = s_X s_W / s_Y`                    (float32)
//! * `w_zp_term[j] = z_X * Σ_k W_q[k, j]`              (int32)
//! * `kzxzw        = K z_X z_W`                        (int32)
//!
//! leaving only the data-dependent dot product and (when `z_W != 0`) the
//! input row-sum for the runtime kernel. Constant folding works on the
//! container's layouts (colsums here index `[K, N]` / `[Cout, kkc]` /
//! `[KH*KW, Cout]` directly); the sibling [`super::pack`] pass then
//! rewrites the weight payloads themselves into kernel layout — both run
//! once, offline, inside [`super::plan::CompiledModel::compile`].

use anyhow::{bail, Result};

use crate::format::mfb::{OpCode, Operator, TensorDef};
use crate::tensor::quant::{FusedAct, PreComputed};

/// Fold the constants for a FullyConnected operator (`w` is `[K, N]`).
pub fn preprocess_fully_connected(
    x_t: &TensorDef,
    w_t: &TensorDef,
    b_t: &TensorDef,
    y_t: &TensorDef,
    fused_act: FusedAct,
) -> Result<PreComputed> {
    let (k, n) = match w_t.dims[..] {
        [k, n] => (k, n),
        _ => bail!("FC weights must be 2-D, got {:?}", w_t.dims),
    };
    let w = w_t.data_i8()?;
    let b = b_t.data_i32()?;
    if w.len() != k.checked_mul(n).unwrap_or(usize::MAX) {
        bail!("FC weight payload {} elements != K*N = {k}*{n}", w.len());
    }
    if b.len() != n {
        bail!("FC bias len {} != N {}", b.len(), n);
    }
    let colsum: Vec<i32> = (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
    Ok(PreComputed::fold(
        &b,
        &colsum,
        k,
        x_t.qparams.scale,
        x_t.qparams.zero_point,
        w_t.qparams.scale,
        w_t.qparams.zero_point,
        b_t.qparams.scale,
        b_t.qparams.zero_point,
        y_t.qparams.scale,
        y_t.qparams.zero_point,
        fused_act,
    ))
}

/// Fold the constants for Conv2D (`f` is `[Cout, KH, KW, Cin]`).
pub fn preprocess_conv2d(
    x_t: &TensorDef,
    f_t: &TensorDef,
    b_t: &TensorDef,
    y_t: &TensorDef,
    fused_act: FusedAct,
) -> Result<PreComputed> {
    let (c_out, kkc) = match f_t.dims[..] {
        [co, kh, kw, ci] => (co, kh * kw * ci),
        _ => bail!("Conv2D filters must be 4-D, got {:?}", f_t.dims),
    };
    let f = f_t.data_i8()?;
    let b = b_t.data_i32()?;
    if f.len() != c_out.checked_mul(kkc).unwrap_or(usize::MAX) {
        bail!("Conv2D filter payload {} elements != Cout*KH*KW*Cin = {c_out}*{kkc}", f.len());
    }
    if b.len() != c_out {
        bail!("Conv2D bias len {} != Cout {}", b.len(), c_out);
    }
    let colsum: Vec<i32> = (0..c_out)
        .map(|co| f[co * kkc..(co + 1) * kkc].iter().map(|&v| v as i32).sum())
        .collect();
    Ok(PreComputed::fold(
        &b,
        &colsum,
        kkc,
        x_t.qparams.scale,
        x_t.qparams.zero_point,
        f_t.qparams.scale,
        f_t.qparams.zero_point,
        b_t.qparams.scale,
        b_t.qparams.zero_point,
        y_t.qparams.scale,
        y_t.qparams.zero_point,
        fused_act,
    ))
}

/// Fold the constants for DepthwiseConv2D (`w` is `[1, KH, KW, Cout]`).
pub fn preprocess_depthwise(
    x_t: &TensorDef,
    w_t: &TensorDef,
    b_t: &TensorDef,
    y_t: &TensorDef,
    fused_act: FusedAct,
) -> Result<PreComputed> {
    let (kk, c_out) = match w_t.dims[..] {
        [1, kh, kw, co] => (kh * kw, co),
        _ => bail!("DW filters must be [1,KH,KW,Cout], got {:?}", w_t.dims),
    };
    let w = w_t.data_i8()?;
    let b = b_t.data_i32()?;
    if w.len() != kk.checked_mul(c_out).unwrap_or(usize::MAX) {
        bail!("DW filter payload {} elements != KH*KW*Cout = {kk}*{c_out}", w.len());
    }
    if b.len() != c_out {
        bail!("DW bias len {} != Cout {}", b.len(), c_out);
    }
    let colsum: Vec<i32> =
        (0..c_out).map(|co| (0..kk).map(|t| w[t * c_out + co] as i32).sum()).collect();
    Ok(PreComputed::fold(
        &b,
        &colsum,
        kk,
        x_t.qparams.scale,
        x_t.qparams.zero_point,
        w_t.qparams.scale,
        w_t.qparams.zero_point,
        b_t.qparams.scale,
        b_t.qparams.zero_point,
        y_t.qparams.scale,
        y_t.qparams.zero_point,
        fused_act,
    ))
}

/// Decode a fused-activation code from operator options.
pub fn fused_act_of(op: &Operator) -> Result<FusedAct> {
    use crate::format::mfb::OpOptions::*;
    let code = match &op.options {
        FullyConnected { fused_act } => *fused_act,
        Conv2D { fused_act, .. } => *fused_act,
        DepthwiseConv2D { fused_act, .. } => *fused_act,
        AveragePool2D { fused_act, .. } => *fused_act,
        _ => 0,
    };
    FusedAct::from_code(code)
}

/// Sanity checks shared by the planner: operator arity per opcode.
pub fn expected_arity(opcode: OpCode) -> (usize, usize) {
    match opcode {
        OpCode::FullyConnected | OpCode::Conv2D | OpCode::DepthwiseConv2D => (3, 1),
        _ => (1, 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{DType, QParams};

    fn td(dims: Vec<usize>, qp: QParams, data_i8: Option<Vec<i8>>, data_i32: Option<Vec<i32>>) -> TensorDef {
        let (dtype, data) = if let Some(d) = data_i8 {
            (DType::I8, d)
        } else if let Some(d) = data_i32 {
            (DType::I32, d.iter().flat_map(|v| v.to_le_bytes()).map(|b| b as i8).collect())
        } else {
            (DType::I8, Vec::new())
        };
        TensorDef { name: String::new(), dtype, dims, qparams: qp, data }
    }

    #[test]
    fn fc_preprocess_folds_colsums() {
        // K=2, N=2, W = [[1,2],[3,4]] (row-major [K,N]) -> colsums [4, 6]
        let x = td(vec![1, 2], QParams::new(0.5, 2), None, None);
        let w = td(vec![2, 2], QParams::new(0.25, 1), Some(vec![1, 2, 3, 4]), None);
        let b = td(vec![2], QParams::new(0.125, 0), None, Some(vec![8, -8]));
        let y = td(vec![1, 2], QParams::new(1.0, -3), None, None);
        let pc = preprocess_fully_connected(&x, &w, &b, &y, FusedAct::None).unwrap();
        assert_eq!(pc.w_zp_term, vec![8, 12]); // z_x(2) * colsum
        assert_eq!(pc.kzxzw, 4); // K(2) * z_x(2) * z_w(1)
        assert_eq!(pc.z_w, 1);
        assert!((pc.scale_ratio - 0.125).abs() < 1e-7);
        assert!((pc.const_bias[0] - (-3.0 + 0.125 * 8.0)).abs() < 1e-6);
        assert!((pc.const_bias[1] - (-3.0 - 0.125 * 8.0)).abs() < 1e-6);
    }

    #[test]
    fn dw_preprocess_uses_per_channel_sums() {
        // KK=2 (1x2 kernel), Cout=2, W layout [t*cout + co]
        let x = td(vec![1, 1, 2, 2], QParams::new(0.5, 3), None, None);
        let w = td(vec![1, 1, 2, 2], QParams::new(0.25, 0), Some(vec![1, 10, 2, 20]), None);
        let b = td(vec![2], QParams::new(0.125, 0), None, Some(vec![0, 0]));
        let y = td(vec![1, 1, 1, 2], QParams::new(1.0, 0), None, None);
        let pc = preprocess_depthwise(&x, &w, &b, &y, FusedAct::None).unwrap();
        assert_eq!(pc.w_zp_term, vec![9, 90]); // 3 * (1+2), 3 * (10+20)
        assert_eq!(pc.kzxzw, 0); // z_w == 0
    }

    #[test]
    fn shape_errors_are_reported() {
        let x = td(vec![1, 2], QParams::NONE, None, None);
        let w = td(vec![4], QParams::NONE, Some(vec![0; 4]), None);
        let b = td(vec![2], QParams::NONE, None, Some(vec![0, 0]));
        let y = td(vec![1, 2], QParams::NONE, None, None);
        assert!(preprocess_fully_connected(&x, &w, &b, &y, FusedAct::None).is_err());
    }
}
