//! Static memory planning (paper Sec. 4.1-4.2; DESIGN.md S7).
//!
//! MicroFlow allocates everything on the stack: during execution the live
//! set at operator `i` is `input_i + output_i + scratch_i` (+ the folded
//! constants and packed weights, which live in Flash/rodata, not RAM).
//! The engine therefore needs exactly two ping-pong activation buffers
//! sized by the largest activations, plus the largest kernel scratch
//! (view/page buffer) — and the **peak** over operators is the device's
//! RAM high-water mark (what Fig. 9/10 plot for MicroFlow).
//!
//! The register-tiled kernel core keeps all dot-product accumulators in
//! registers (`microkernel::NR` per walk), so no step charges i32
//! accumulator scratch anymore — the wide-output FullyConnected buffer
//! that PR 2 threaded through the plan is gone entirely.
//!
//! Contrast with the TFLM arena ([`crate::interp::arena`]): sized for the
//! worst case, allocated for the whole lifetime, never freed.

use super::plan::{Step, StepKind};

/// Per-step memory accounting (bytes).
#[derive(Clone, Debug, PartialEq)]
pub struct StepMemory {
    pub op: &'static str,
    pub input: usize,
    pub output: usize,
    pub scratch: usize,
}

impl StepMemory {
    pub fn live(&self) -> usize {
        self.input + self.output + self.scratch
    }
}

/// The static memory plan for a compiled model.
#[derive(Clone, Debug)]
pub struct MemoryPlan {
    pub per_step: Vec<StepMemory>,
    /// RAM high-water mark across the inference (bytes): the MicroFlow
    /// number in the paper's RAM plots.
    pub peak: usize,
    /// Index of the peak step.
    pub peak_step: usize,
    /// Sizes of the two ping-pong buffers the executor allocates.
    pub buf_a: usize,
    pub buf_b: usize,
    /// Largest kernel scratch (view/page buffer).
    pub scratch: usize,
}

impl MemoryPlan {
    /// Analyze a step sequence.
    pub fn analyze(steps: &[Step]) -> MemoryPlan {
        let mut per_step = Vec::with_capacity(steps.len());
        let mut peak = 0usize;
        let mut peak_step = 0usize;
        // ping-pong: even steps read A write B, odd steps read B write A;
        // reshape is free (same buffer reinterpreted)
        let mut buf_a = 0usize;
        let mut buf_b = 0usize;
        let mut scratch = 0usize;
        let mut reads_a = true;
        for (i, s) in steps.iter().enumerate() {
            let m = StepMemory {
                op: s.kind.name(),
                input: s.in_len,
                output: if matches!(s.kind, StepKind::Reshape) { 0 } else { s.out_len },
                scratch: s.scratch_len,
            };
            if m.live() > peak {
                peak = m.live();
                peak_step = i;
            }
            if matches!(s.kind, StepKind::Reshape) {
                // in-place: no buffer flip, no new allocation
                per_step.push(m);
                continue;
            }
            if reads_a {
                buf_a = buf_a.max(s.in_len);
                buf_b = buf_b.max(s.out_len);
            } else {
                buf_b = buf_b.max(s.in_len);
                buf_a = buf_a.max(s.out_len);
            }
            scratch = scratch.max(s.scratch_len);
            reads_a = !reads_a;
            per_step.push(m);
        }
        MemoryPlan { per_step, peak, peak_step, buf_a, buf_b, scratch }
    }

    /// Total bytes the executor actually allocates (ping-pong + scratch).
    pub fn executor_bytes(&self) -> usize {
        self.buf_a + self.buf_b + self.scratch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::pack::pack_conv2d;
    use crate::compiler::plan::{Step, StepKind};
    use crate::format::mfb::Padding;
    use crate::kernels::view::ConvGeometry;
    use crate::tensor::quant::{FusedAct, PreComputed};

    fn fc_step(k: usize, n: usize) -> Step {
        let pc = PreComputed::fold(
            &vec![0; n],
            &vec![0; n],
            k,
            0.1,
            0,
            0.1,
            0,
            0.01,
            0,
            0.1,
            0,
            FusedAct::None,
        );
        Step {
            kind: StepKind::FullyConnected { k, n, weights: vec![0; k * n], pc, paged: false },
            in_len: k,
            out_len: n,
            scratch_len: 0,
        }
    }

    #[test]
    fn peak_is_biggest_live_set() {
        let steps = vec![fc_step(10, 100), fc_step(100, 4)];
        let plan = MemoryPlan::analyze(&steps);
        // register-tiled FC: input + output only, no accumulator scratch
        assert_eq!(plan.peak, 110);
        assert_eq!(plan.peak_step, 0);
        // ping-pong sizing: A holds inputs of even steps + outputs of odd
        assert_eq!(plan.buf_a, 10.max(4));
        assert_eq!(plan.buf_b, 100);
        assert_eq!(plan.executor_bytes(), 10 + 100 + 0);
    }

    #[test]
    fn paged_fc_charges_its_page_buffer() {
        let mut paged = fc_step(64, 32);
        if let StepKind::FullyConnected { paged: p, .. } = &mut paged.kind {
            *p = true;
        }
        paged.scratch_len = 64; // page buffer
        let plan = MemoryPlan::analyze(&[paged]);
        assert_eq!(plan.scratch, 64);
        assert_eq!(plan.peak, 64 + 32 + 64);
    }

    #[test]
    fn reshape_is_free() {
        let mut steps = vec![fc_step(8, 8)];
        steps.push(Step { kind: StepKind::Reshape, in_len: 8, out_len: 8, scratch_len: 0 });
        steps.push(fc_step(8, 2));
        let plan = MemoryPlan::analyze(&steps);
        // reshape contributes no output copy
        assert_eq!(plan.per_step[1].output, 0);
        // second FC still reads buffer B (no flip on reshape)
        assert_eq!(plan.buf_a, 8);
        assert_eq!(plan.buf_b, 8);
    }

    #[test]
    fn conv_scratch_counts_toward_peak() {
        let geo = ConvGeometry::new(8, 8, 4, 3, 3, 1, 1, Padding::Same).unwrap();
        let pc = PreComputed::fold(&[0], &[0], 36, 0.1, 0, 0.1, 0, 0.01, 0, 0.1, 0, FusedAct::None);
        let step = Step {
            kind: StepKind::Conv2D {
                geo,
                filters: pack_conv2d(&[0; 36], 1, 36),
                z_x: 0,
                pc,
            },
            in_len: 8 * 8 * 4,
            out_len: 8 * 8,
            scratch_len: 36,
        };
        let plan = MemoryPlan::analyze(&[step]);
        assert_eq!(plan.peak, 256 + 64 + 36);
        assert_eq!(plan.scratch, 36);
    }
}
