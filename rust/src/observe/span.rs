//! Hot-path span recorder: preallocated, fixed-capacity ring buffers of
//! POD span events covering the request lifecycle
//! (admit → queue → batch → execute → reply).
//!
//! The record path is the whole point of this design:
//!
//! * **zero allocation** — every slot is preallocated at ring
//!   construction; recording stores four machine words;
//! * **zero locks** — slots are claimed with one `fetch_add` on the
//!   ring's write counter and published with a per-slot sequence number
//!   (a seqlock written entirely through atomics, so the race is
//!   detected, never undefined behavior);
//! * **wait-free** — a full ring *overwrites* the oldest events rather
//!   than blocking or erroring. The drain side counts every overwritten
//!   or torn slot in [`SpanWindow::dropped`], so loss is visible, not
//!   silent.
//!
//! The drain path is single-consumer by contract: [`SpanRing::drain`] is
//! only called from the deployment's tick loop (the same place that
//! consumes [`Metrics::window`](crate::coordinator::Metrics::window)),
//! which is what keeps the exporter read-only — no policy decision ever
//! reads a span ring, and no reader ever touches the record path's cache
//! lines outside the tick.

use std::sync::atomic::{fence, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

/// Request-lifecycle phase of one span event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Accepted by a pool's submit path (counted `submitted`).
    Admit,
    /// Claimed off the shared queue into a worker's batch assembly.
    Queue,
    /// Batch cut complete — the request is about to execute.
    Batch,
    /// The batch executed successfully (kernel work done).
    Execute,
    /// The reply was delivered to the ticket.
    Reply,
}

/// Number of [`Phase`] variants (sizes the per-phase count tables).
pub const PHASE_COUNT: usize = 5;

impl Phase {
    pub const ALL: [Phase; PHASE_COUNT] =
        [Phase::Admit, Phase::Queue, Phase::Batch, Phase::Execute, Phase::Reply];

    /// Dense index for per-phase count arrays.
    pub fn index(self) -> usize {
        match self {
            Phase::Admit => 0,
            Phase::Queue => 1,
            Phase::Batch => 2,
            Phase::Execute => 3,
            Phase::Reply => 4,
        }
    }

    /// Stable lowercase name (metric label values).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Admit => "admit",
            Phase::Queue => "queue",
            Phase::Batch => "batch",
            Phase::Execute => "execute",
            Phase::Reply => "reply",
        }
    }
}

impl std::fmt::Display for Phase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Default ring capacity in events (power of two; index masks, no `%`).
pub const SPAN_RING_CAPACITY: usize = 1024;

/// QoS-class lanes in the count tables (mirrors `QosClass::ALL`).
pub const CLASS_LANES: usize = 3;

/// One preallocated event slot. All fields are atomics so a torn
/// concurrent write is a *detected data race*, never undefined behavior:
/// `seq` runs the classic seqlock protocol (odd = in progress, `2n + 2` =
/// generation `n` published).
struct Slot {
    seq: AtomicU64,
    id: AtomicU64,
    t_us: AtomicU64,
    /// `class.index()` in the low byte, `phase.index()` in the next.
    meta: AtomicU32,
}

/// A fixed-capacity ring of span events.
///
/// Writers claim a slot with `fetch_add` on `written` (so the ring is
/// safe even with several recording threads — the per-slot sequence
/// number detects a writer that lapped another mid-write); the single
/// drainer walks `[drained, written)` and skips any slot whose sequence
/// does not match its generation, counting it dropped.
pub struct SpanRing {
    slots: Box<[Slot]>,
    written: AtomicU64,
    /// Consumed cursor — only the (single) drainer touches it.
    drained: AtomicU64,
    epoch: Instant,
}

impl SpanRing {
    /// Ring with the default capacity ([`SPAN_RING_CAPACITY`]).
    pub fn new() -> SpanRing {
        SpanRing::with_capacity(SPAN_RING_CAPACITY)
    }

    /// Ring with an explicit capacity (rounded up to a power of two so
    /// slot indexing is a mask).
    pub fn with_capacity(capacity: usize) -> SpanRing {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Vec<Slot> = (0..cap)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                id: AtomicU64::new(0),
                t_us: AtomicU64::new(0),
                meta: AtomicU32::new(0),
            })
            .collect();
        SpanRing {
            slots: slots.into_boxed_slice(),
            written: AtomicU64::new(0),
            drained: AtomicU64::new(0),
            epoch: Instant::now(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Events ever recorded (including any later overwritten).
    pub fn recorded(&self) -> u64 {
        self.written.load(Ordering::Relaxed)
    }

    /// Record one span event. The hot path: no allocation, no locks, one
    /// `fetch_add` plus four plain atomic stores. `class` is the dense
    /// `QosClass::index()` (values `>= CLASS_LANES` are clamped into the
    /// last lane rather than dropped).
    pub fn record(&self, id: u64, class: u8, phase: Phase) {
        let n = self.written.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(n as usize) & (self.slots.len() - 1)];
        // odd = write in progress; generation-tagged so a drain racing
        // this write (or a writer a full lap behind) reads a mismatch
        slot.seq.store(2 * n + 1, Ordering::Release);
        slot.id.store(id, Ordering::Relaxed);
        slot.t_us.store(self.epoch.elapsed().as_micros() as u64, Ordering::Relaxed);
        let lane = (class as u32).min(CLASS_LANES as u32 - 1);
        slot.meta.store(lane | ((phase.index() as u32) << 8), Ordering::Relaxed);
        slot.seq.store(2 * n + 2, Ordering::Release);
    }

    /// Drain every event recorded since the previous drain into `w`.
    /// Single-consumer by contract (the tick loop); events overwritten
    /// before this drain reached them — or torn by a racing writer — are
    /// counted in [`SpanWindow::dropped`]. Allocation-free.
    pub fn drain(&self, w: &mut SpanWindow) {
        let cap = self.slots.len() as u64;
        let end = self.written.load(Ordering::Acquire);
        let consumed = self.drained.load(Ordering::Relaxed);
        // anything more than one lap behind was overwritten unread
        let start = consumed.max(end.saturating_sub(cap));
        w.dropped += start - consumed;
        for n in start..end {
            let slot = &self.slots[(n as usize) & (self.slots.len() - 1)];
            if slot.seq.load(Ordering::Acquire) != 2 * n + 2 {
                w.dropped += 1;
                continue;
            }
            let t_us = slot.t_us.load(Ordering::Relaxed);
            let meta = slot.meta.load(Ordering::Relaxed);
            // re-check after the field loads: a writer lapping us mid-read
            // bumps the sequence, so a torn read is discarded, not counted
            fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) != 2 * n + 2 {
                w.dropped += 1;
                continue;
            }
            let class = (meta & 0xff) as usize;
            let phase = ((meta >> 8) & 0xff) as usize;
            w.recorded += 1;
            w.counts[phase.min(PHASE_COUNT - 1)][class.min(CLASS_LANES - 1)] += 1;
            w.last_t_us = w.last_t_us.max(t_us);
        }
        self.drained.store(end, Ordering::Relaxed);
    }
}

impl Default for SpanRing {
    fn default() -> Self {
        SpanRing::new()
    }
}

/// Aggregated counts drained out of one or more span rings — what the
/// exposition tier consumes. Plain data, mergeable, allocation-free.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpanWindow {
    /// Events successfully drained into `counts`.
    pub recorded: u64,
    /// Events lost to ring overwrite or torn by a racing writer.
    pub dropped: u64,
    /// `counts[phase][class]` event counts (dense indices).
    pub counts: [[u64; CLASS_LANES]; PHASE_COUNT],
    /// Largest event timestamp seen, in µs since the ring's epoch.
    pub last_t_us: u64,
}

impl SpanWindow {
    /// Events in `phase` summed over classes.
    pub fn by_phase(&self, phase: Phase) -> u64 {
        self.counts[phase.index()].iter().sum()
    }

    /// Events in class lane `class` summed over phases.
    pub fn by_class(&self, class: usize) -> u64 {
        self.counts.iter().map(|p| p[class.min(CLASS_LANES - 1)]).sum()
    }

    /// Fold another window into this one.
    pub fn merge(&mut self, other: &SpanWindow) {
        self.recorded += other.recorded;
        self.dropped += other.dropped;
        for (mine, theirs) in self.counts.iter_mut().zip(other.counts.iter()) {
            for (m, t) in mine.iter_mut().zip(theirs.iter()) {
                *m += *t;
            }
        }
        self.last_t_us = self.last_t_us.max(other.last_t_us);
    }
}

/// One pool's span-recording surface: a ring for the admission path
/// (written by submitting threads) plus one ring per registered worker
/// (single-writer by construction). Draining walks every ring; the
/// registry lock is only ever taken at worker registration and at drain —
/// never on the record path.
pub struct SpanRecorder {
    admit: Arc<SpanRing>,
    workers: RwLock<Vec<Arc<SpanRing>>>,
}

impl SpanRecorder {
    pub fn new() -> SpanRecorder {
        SpanRecorder { admit: Arc::new(SpanRing::new()), workers: RwLock::new(Vec::new()) }
    }

    /// Record one admission-path event (submit side). Lock-free,
    /// allocation-free.
    pub fn record_admit(&self, id: u64, class: u8, phase: Phase) {
        self.admit.record(id, class, phase);
    }

    /// Register a worker's private ring (called once at worker spawn; the
    /// worker keeps the handle and records on it without any further
    /// coordination).
    pub fn register_worker(&self) -> Arc<SpanRing> {
        let ring = Arc::new(SpanRing::new());
        self.workers.write().unwrap().push(Arc::clone(&ring));
        ring
    }

    /// Drain the admission ring and every worker ring into one merged
    /// window. Single consumer by contract: the tick loop.
    pub fn drain_window(&self) -> SpanWindow {
        let mut w = SpanWindow::default();
        self.admit.drain(&mut w);
        for ring in self.workers.read().unwrap().iter() {
            ring.drain(&mut w);
        }
        w
    }
}

impl Default for SpanRecorder {
    fn default() -> Self {
        SpanRecorder::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_then_drain_roundtrips_counts() {
        let ring = SpanRing::with_capacity(8);
        ring.record(1, 0, Phase::Admit);
        ring.record(1, 0, Phase::Execute);
        ring.record(2, 1, Phase::Admit);
        let mut w = SpanWindow::default();
        ring.drain(&mut w);
        assert_eq!(w.recorded, 3);
        assert_eq!(w.dropped, 0);
        assert_eq!(w.by_phase(Phase::Admit), 2);
        assert_eq!(w.by_phase(Phase::Execute), 1);
        assert_eq!(w.counts[Phase::Admit.index()][1], 1);
        assert_eq!(w.by_class(0), 2);
        // a second drain sees nothing new
        let mut w2 = SpanWindow::default();
        ring.drain(&mut w2);
        assert_eq!((w2.recorded, w2.dropped), (0, 0));
    }

    #[test]
    fn overwrite_is_counted_as_dropped_never_silent() {
        let ring = SpanRing::with_capacity(4);
        for i in 0..10 {
            ring.record(i, 0, Phase::Admit);
        }
        let mut w = SpanWindow::default();
        ring.drain(&mut w);
        // 10 recorded into 4 slots: the newest 4 survive, 6 were lapped
        assert_eq!(w.recorded, 4);
        assert_eq!(w.dropped, 6);
        assert_eq!(ring.recorded(), 10);
    }

    #[test]
    fn timestamps_are_monotonic_per_ring() {
        let ring = SpanRing::new();
        ring.record(1, 0, Phase::Admit);
        std::thread::sleep(std::time::Duration::from_millis(2));
        ring.record(1, 0, Phase::Reply);
        let mut w = SpanWindow::default();
        ring.drain(&mut w);
        assert!(w.last_t_us >= 2_000, "t={}", w.last_t_us);
    }

    #[test]
    fn concurrent_writers_never_corrupt_a_drain() {
        // several threads hammer one ring while the main thread drains;
        // every drained event must carry a valid phase/class pair and
        // recorded + dropped must equal the claimed total at quiescence
        let ring = Arc::new(SpanRing::with_capacity(64));
        let mut handles = Vec::new();
        for t in 0..4u8 {
            let ring = Arc::clone(&ring);
            handles.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    ring.record(i, t % 3, Phase::ALL[(i % 5) as usize]);
                }
            }));
        }
        let mut w = SpanWindow::default();
        for _ in 0..50 {
            ring.drain(&mut w);
            std::thread::yield_now();
        }
        for h in handles {
            h.join().unwrap();
        }
        ring.drain(&mut w);
        assert_eq!(w.recorded + w.dropped, 2000, "{w:?}");
        let table_total: u64 = w.counts.iter().flatten().sum();
        assert_eq!(table_total, w.recorded);
    }

    #[test]
    fn recorder_merges_admit_and_worker_rings() {
        let rec = SpanRecorder::new();
        rec.record_admit(7, 0, Phase::Admit);
        let worker = rec.register_worker();
        worker.record(7, 0, Phase::Queue);
        worker.record(7, 0, Phase::Execute);
        worker.record(7, 0, Phase::Reply);
        let w = rec.drain_window();
        assert_eq!(w.recorded, 4);
        for phase in [Phase::Admit, Phase::Queue, Phase::Execute, Phase::Reply] {
            assert_eq!(w.by_phase(phase), 1, "{phase}");
        }
        assert_eq!(w.by_phase(Phase::Batch), 0);
    }
}
