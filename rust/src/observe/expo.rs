//! Exposition tier: a Prometheus-text-format snapshot assembled **only**
//! from drained windows.
//!
//! [`Exposition`] is the single sink the deployment's tick loop feeds:
//! [`Exposition::absorb_tick`] folds each [`PoolTickReport`] (the consumed
//! metrics window, the drained span window, the cumulative per-step
//! profile rows, breaker/autoscale/ejection outcomes) into per-pool
//! accumulators, and [`Exposition::absorb_streams`] folds a
//! [`StreamHostSnapshot`]. [`Exposition::render`] then serializes the
//! accumulated state — it never touches a `Metrics`, a span ring or any
//! other live counter, which is what keeps the exporter read-only and the
//! window cursor single-consumer.
//!
//! Because the request lanes are accumulated from window *deltas*, the
//! exported counters satisfy the lifecycle identity
//! `completed + shed + cancelled + failed == submitted` per pool and per
//! class whenever the pools are quiescent at tick time — re-asserted on
//! the exported text itself by [`Exposition::identity_holds`] and the
//! scrape-smoke suite.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::{TcpListener, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::profile::StepProfileRow;
use super::span::{SpanWindow, CLASS_LANES, PHASE_COUNT};
use crate::coordinator::autoscale::ScaleAction;
use crate::coordinator::fleet::PoolTickReport;
use crate::coordinator::resilience::BreakerState;
use crate::coordinator::stream::StreamHostSnapshot;

/// QoS lane names in dense-index order (mirrors `QosClass::ALL`).
const CLASS_NAMES: [&str; CLASS_LANES] = ["interactive", "bulk", "background"];
/// Phase names in dense-index order (mirrors `Phase::ALL`).
const PHASE_NAMES: [&str; PHASE_COUNT] = ["admit", "queue", "batch", "execute", "reply"];

/// One class lane's accumulated lifecycle counters.
#[derive(Clone, Copy, Debug, Default)]
struct LaneAcc {
    submitted: u64,
    completed: u64,
    shed: u64,
    cancelled: u64,
    failed: u64,
    retried: u64,
    deadline_missed: u64,
}

/// One pool's accumulated exposition state.
#[derive(Debug, Default)]
struct PoolExpo {
    lanes: [LaneAcc; CLASS_LANES],
    /// Latest window's p95 per class (gauge).
    p95_us: [f64; CLASS_LANES],
    live_replicas: usize,
    breaker: Option<BreakerState>,
    ejected_total: u64,
    scale_up_total: u64,
    scale_down_total: u64,
    spans: SpanWindow,
    /// Cumulative per-step rows, replaced wholesale each tick (the
    /// shared profile's counters are monotonic already).
    profile: Vec<StepProfileRow>,
}

/// One stream host's latest aggregated counters. Streams leave the
/// aggregate when closed, so these are exported from the most recent
/// snapshot rather than accumulated (the per-stream identity still holds
/// within any one snapshot).
#[derive(Clone, Copy, Debug, Default)]
struct StreamExpo {
    submitted: u64,
    completed: u64,
    shed: u64,
    cancelled: u64,
    failed: u64,
    verdicts: u64,
}

#[derive(Debug, Default)]
struct ExpoState {
    pools: BTreeMap<String, PoolExpo>,
    streams: BTreeMap<String, StreamExpo>,
}

/// The metrics sink + renderer (module docs have the contract). Shareable:
/// the tick loop absorbs, any number of scrapers render.
#[derive(Default)]
pub struct Exposition {
    state: Mutex<ExpoState>,
}

impl Exposition {
    pub fn new() -> Exposition {
        Exposition::default()
    }

    /// Fold one tick's reports into the accumulators. Called from the
    /// deployment's tick loop only — the reports carry everything the
    /// exporter needs, already drained.
    pub fn absorb_tick(&self, reports: &[PoolTickReport]) {
        let mut st = self.state.lock().unwrap();
        for r in reports {
            let p = st.pools.entry(r.pool.clone()).or_default();
            for (i, c) in r.window.per_class.iter().enumerate() {
                let lane = &mut p.lanes[i];
                lane.submitted += c.submitted;
                lane.completed += c.completed;
                lane.shed += c.shed;
                lane.cancelled += c.cancelled;
                lane.failed += c.failed;
                lane.retried += c.retried;
                lane.deadline_missed += c.deadline_missed;
                if c.completed > 0 {
                    p.p95_us[i] = c.p95_us;
                }
            }
            p.live_replicas = r.live_replicas;
            p.breaker = r.breaker;
            p.ejected_total += r.ejected.len() as u64;
            match r.decision.map(|d| d.action) {
                Some(ScaleAction::Up(_)) => p.scale_up_total += 1,
                Some(ScaleAction::Down(_)) => p.scale_down_total += 1,
                _ => {}
            }
            p.spans.merge(&r.spans);
            if !r.profile.is_empty() {
                p.profile = r.profile.clone();
            }
        }
    }

    /// Fold one stream host's snapshot (keyed by model name).
    pub fn absorb_streams(&self, model: &str, snap: &StreamHostSnapshot) {
        let mut agg = StreamExpo::default();
        for s in &snap.streams {
            agg.submitted += s.counters.submitted;
            agg.completed += s.counters.completed;
            agg.shed += s.counters.shed;
            agg.cancelled += s.counters.cancelled;
            agg.failed += s.counters.failed;
            agg.verdicts += s.counters.verdicts;
        }
        self.state.lock().unwrap().streams.insert(model.to_string(), agg);
    }

    /// Does every pool's every class lane satisfy
    /// `completed + shed + cancelled + failed == submitted` in the
    /// accumulated state? True exactly when the pools were quiescent at
    /// the last absorbed tick.
    pub fn identity_holds(&self) -> bool {
        let st = self.state.lock().unwrap();
        st.pools.values().all(|p| {
            p.lanes
                .iter()
                .all(|l| l.completed + l.shed + l.cancelled + l.failed == l.submitted)
        })
    }

    /// Serialize the accumulated state as Prometheus text format
    /// (version 0.0.4): one `# HELP`/`# TYPE` pair per family, stable
    /// (sorted) ordering, label values escaped.
    pub fn render(&self) -> String {
        let st = self.state.lock().unwrap();
        let mut out = String::new();
        let family = |out: &mut String, name: &str, help: &str, kind: &str| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} {kind}");
        };

        family(
            &mut out,
            "microflow_requests_total",
            "Request lifecycle counters per pool, class and outcome.",
            "counter",
        );
        for (name, p) in st.pools.iter() {
            let pool = escape_label(name);
            for (i, lane) in p.lanes.iter().enumerate() {
                let class = CLASS_NAMES[i];
                for (outcome, v) in [
                    ("submitted", lane.submitted),
                    ("completed", lane.completed),
                    ("shed", lane.shed),
                    ("cancelled", lane.cancelled),
                    ("failed", lane.failed),
                    ("retried", lane.retried),
                    ("deadline_missed", lane.deadline_missed),
                ] {
                    let _ = writeln!(
                        out,
                        "microflow_requests_total{{pool=\"{pool}\",class=\"{class}\",outcome=\"{outcome}\"}} {v}"
                    );
                }
            }
        }

        family(
            &mut out,
            "microflow_window_p95_us",
            "p95 latency of the most recent active window, microseconds.",
            "gauge",
        );
        for (name, p) in st.pools.iter() {
            let pool = escape_label(name);
            for (i, v) in p.p95_us.iter().enumerate() {
                let _ = writeln!(
                    out,
                    "microflow_window_p95_us{{pool=\"{pool}\",class=\"{}\"}} {v}",
                    CLASS_NAMES[i]
                );
            }
        }

        family(&mut out, "microflow_replicas", "Live replicas per pool.", "gauge");
        for (name, p) in st.pools.iter() {
            let _ = writeln!(
                out,
                "microflow_replicas{{pool=\"{}\"}} {}",
                escape_label(name),
                p.live_replicas
            );
        }

        family(
            &mut out,
            "microflow_breaker_state",
            "Circuit breaker state per pool (0=closed, 1=open, 2=half-open).",
            "gauge",
        );
        for (name, p) in st.pools.iter() {
            if let Some(b) = p.breaker {
                let _ = writeln!(
                    out,
                    "microflow_breaker_state{{pool=\"{}\"}} {}",
                    escape_label(name),
                    b.as_u8()
                );
            }
        }

        family(
            &mut out,
            "microflow_replicas_ejected_total",
            "Replicas ejected by the health pass per pool.",
            "counter",
        );
        for (name, p) in st.pools.iter() {
            let _ = writeln!(
                out,
                "microflow_replicas_ejected_total{{pool=\"{}\"}} {}",
                escape_label(name),
                p.ejected_total
            );
        }

        family(
            &mut out,
            "microflow_autoscale_decisions_total",
            "Applied autoscale decisions per pool and direction.",
            "counter",
        );
        for (name, p) in st.pools.iter() {
            let pool = escape_label(name);
            let _ = writeln!(
                out,
                "microflow_autoscale_decisions_total{{pool=\"{pool}\",action=\"up\"}} {}",
                p.scale_up_total
            );
            let _ = writeln!(
                out,
                "microflow_autoscale_decisions_total{{pool=\"{pool}\",action=\"down\"}} {}",
                p.scale_down_total
            );
        }

        family(
            &mut out,
            "microflow_span_events_total",
            "Span events drained per pool, request phase and class.",
            "counter",
        );
        for (name, p) in st.pools.iter() {
            let pool = escape_label(name);
            for (pi, phase) in PHASE_NAMES.iter().enumerate() {
                for (ci, class) in CLASS_NAMES.iter().enumerate() {
                    let _ = writeln!(
                        out,
                        "microflow_span_events_total{{pool=\"{pool}\",phase=\"{phase}\",class=\"{class}\"}} {}",
                        p.spans.counts[pi][ci]
                    );
                }
            }
        }

        family(
            &mut out,
            "microflow_spans_dropped_total",
            "Span events lost to ring overwrite per pool.",
            "counter",
        );
        for (name, p) in st.pools.iter() {
            let _ = writeln!(
                out,
                "microflow_spans_dropped_total{{pool=\"{}\"}} {}",
                escape_label(name),
                p.spans.dropped
            );
        }

        family(
            &mut out,
            "microflow_step_invocations_total",
            "Plan-step kernel invocations per pool and step.",
            "counter",
        );
        for (name, p) in st.pools.iter() {
            let pool = escape_label(name);
            for row in &p.profile {
                let _ = writeln!(
                    out,
                    "microflow_step_invocations_total{{pool=\"{pool}\",step=\"{}\",kind=\"{}\"}} {}",
                    row.step, row.kind, row.invocations
                );
            }
        }

        family(
            &mut out,
            "microflow_step_ns_total",
            "Plan-step kernel nanoseconds per pool and step.",
            "counter",
        );
        for (name, p) in st.pools.iter() {
            let pool = escape_label(name);
            for row in &p.profile {
                let _ = writeln!(
                    out,
                    "microflow_step_ns_total{{pool=\"{pool}\",step=\"{}\",kind=\"{}\"}} {}",
                    row.step, row.kind, row.total_ns
                );
            }
        }

        family(
            &mut out,
            "microflow_stream_pushes_total",
            "Stream push lifecycle counters per model and outcome (open streams).",
            "counter",
        );
        for (model, s) in st.streams.iter() {
            let m = escape_label(model);
            for (outcome, v) in [
                ("submitted", s.submitted),
                ("completed", s.completed),
                ("shed", s.shed),
                ("cancelled", s.cancelled),
                ("failed", s.failed),
            ] {
                let _ = writeln!(
                    out,
                    "microflow_stream_pushes_total{{model=\"{m}\",outcome=\"{outcome}\"}} {v}"
                );
            }
        }

        family(
            &mut out,
            "microflow_stream_verdicts_total",
            "Stream verdicts emitted per model (open streams).",
            "counter",
        );
        for (model, s) in st.streams.iter() {
            let _ = writeln!(
                out,
                "microflow_stream_verdicts_total{{model=\"{}\"}} {}",
                escape_label(model),
                s.verdicts
            );
        }

        out
    }
}

/// Escape a label value per the Prometheus text format: backslash, double
/// quote and newline.
pub fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// One parsed sample off an exposition body.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Sample {
    /// Value of label `key`, if present.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// Parse a Prometheus-text-format body back into samples (label escapes
/// reversed). The inverse of [`Exposition::render`] — what `microflow
/// top` and the scrape tests consume. Comment/blank lines are skipped;
/// malformed lines are dropped rather than failing the whole body.
pub fn parse_exposition(text: &str) -> Vec<Sample> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (head, value) = match line.rfind(' ') {
            Some(i) => (&line[..i], &line[i + 1..]),
            None => continue,
        };
        let Ok(value) = value.parse::<f64>() else { continue };
        let (name, labels) = match head.find('{') {
            None => (head.to_string(), Vec::new()),
            Some(open) => {
                let Some(close) = head.rfind('}') else { continue };
                let name = head[..open].to_string();
                let mut labels = Vec::new();
                let body = &head[open + 1..close];
                let mut chars = body.chars().peekable();
                'pairs: while chars.peek().is_some() {
                    let mut key = String::new();
                    for c in chars.by_ref() {
                        if c == '=' {
                            break;
                        }
                        key.push(c);
                    }
                    if chars.next() != Some('"') {
                        break 'pairs;
                    }
                    let mut val = String::new();
                    loop {
                        match chars.next() {
                            Some('\\') => match chars.next() {
                                Some('\\') => val.push('\\'),
                                Some('"') => val.push('"'),
                                Some('n') => val.push('\n'),
                                Some(c) => val.push(c),
                                None => break 'pairs,
                            },
                            Some('"') => break,
                            Some(c) => val.push(c),
                            None => break 'pairs,
                        }
                    }
                    labels.push((key, val));
                    if chars.peek() == Some(&',') {
                        chars.next();
                    }
                }
                (name, labels)
            }
        };
        out.push(Sample { name, labels, value });
    }
    out
}

/// A minimal blocking HTTP/1.0 exposition endpoint: every request (any
/// path) is answered with the current [`Exposition::render`] body. Built
/// on the non-blocking std listener + one thread — no async runtime, no
/// HTTP library, matching the repo's hand-rolled wire tier.
pub struct MetricsServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9100`; port 0 picks a free port) and
    /// start serving scrapes of `expo`.
    pub fn start(addr: impl ToSocketAddrs, expo: Arc<Exposition>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr).context("binding metrics listener")?;
        let addr = listener.local_addr().context("metrics listener addr")?;
        listener.set_nonblocking(true).context("metrics listener nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("mf-metrics".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut conn, _)) => {
                            let _ = conn.set_nonblocking(false);
                            // best-effort request drain: one read is enough
                            // for any sane scraper's GET line + headers
                            let mut buf = [0u8; 1024];
                            let _ = conn.read(&mut buf);
                            let body = expo.render();
                            let head = format!(
                                "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
                                body.len()
                            );
                            let _ = conn.write_all(head.as_bytes());
                            let _ = conn.write_all(body.as_bytes());
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawning metrics thread")?;
        Ok(MetricsServer { addr, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting and join the serving thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::autoscale::{Decision, ScaleReason};
    use crate::coordinator::metrics::{ClassWindow, WindowSnapshot};
    use crate::coordinator::request::QosClass;
    use std::time::Duration;

    fn lane(class: QosClass, submitted: u64, completed: u64, shed: u64) -> ClassWindow {
        ClassWindow {
            class,
            submitted,
            completed,
            failed: 0,
            retried: 0,
            shed,
            cancelled: 0,
            deadline_missed: 0,
            p50_us: 10.0,
            p95_us: 42.0,
        }
    }

    fn report(pool: &str) -> PoolTickReport {
        let mut counts = [[0u64; CLASS_LANES]; PHASE_COUNT];
        counts[0][0] = 3; // 3 admits, interactive
        let spans = SpanWindow { recorded: 3, counts, ..SpanWindow::default() };
        PoolTickReport {
            pool: pool.to_string(),
            live_replicas: 2,
            decision: Some(Decision {
                action: ScaleAction::Up(1),
                reason: ScaleReason::SloBreach,
            }),
            breaker: Some(BreakerState::Closed),
            ejected: vec!["w0".to_string()],
            window: WindowSnapshot {
                elapsed: Duration::from_secs(1),
                per_class: [
                    lane(QosClass::Interactive, 3, 2, 1),
                    lane(QosClass::Bulk, 0, 0, 0),
                    lane(QosClass::Background, 0, 0, 0),
                ],
            },
            spans,
            profile: vec![
                StepProfileRow { step: 0, kind: "FullyConnected", invocations: 5, total_ns: 1000 },
                StepProfileRow { step: 1, kind: "Softmax", invocations: 5, total_ns: 200 },
            ],
        }
    }

    #[test]
    fn escaping_covers_backslash_quote_and_newline() {
        assert_eq!(escape_label(r#"a\b"c"#), r#"a\\b\"c"#);
        assert_eq!(escape_label("x\ny"), r"x\ny");
        assert_eq!(escape_label("plain"), "plain");
    }

    #[test]
    fn render_is_stable_and_parse_roundtrips_escapes() {
        let expo = Exposition::new();
        expo.absorb_tick(&[report(r#"we"ird\pool"#)]);
        let a = expo.render();
        let b = expo.render();
        assert_eq!(a, b, "rendering must be deterministic");
        let samples = parse_exposition(&a);
        let s = samples
            .iter()
            .find(|s| {
                s.name == "microflow_requests_total"
                    && s.label("class") == Some("interactive")
                    && s.label("outcome") == Some("submitted")
            })
            .expect("submitted sample");
        assert_eq!(s.label("pool"), Some(r#"we"ird\pool"#), "escapes must roundtrip");
        assert_eq!(s.value, 3.0);
    }

    #[test]
    fn lane_identity_is_assertable_on_the_exported_text() {
        let expo = Exposition::new();
        // two ticks accumulate: 6 submitted = 4 completed + 2 shed
        expo.absorb_tick(&[report("pool")]);
        expo.absorb_tick(&[report("pool")]);
        assert!(expo.identity_holds());
        let samples = parse_exposition(&expo.render());
        for class in CLASS_NAMES {
            let get = |outcome: &str| {
                samples
                    .iter()
                    .find(|s| {
                        s.name == "microflow_requests_total"
                            && s.label("class") == Some(class)
                            && s.label("outcome") == Some(outcome)
                    })
                    .map(|s| s.value)
                    .unwrap()
            };
            assert_eq!(
                get("completed") + get("shed") + get("cancelled") + get("failed"),
                get("submitted"),
                "identity broken for class {class}"
            );
        }
    }

    #[test]
    fn control_plane_counters_accumulate_and_profiles_replace() {
        let expo = Exposition::new();
        expo.absorb_tick(&[report("p")]);
        expo.absorb_tick(&[report("p")]);
        let samples = parse_exposition(&expo.render());
        let find = |name: &str, key: &str, val: &str| {
            samples
                .iter()
                .find(|s| s.name == name && s.label(key) == Some(val))
                .map(|s| s.value)
                .unwrap()
        };
        assert_eq!(find("microflow_replicas_ejected_total", "pool", "p"), 2.0);
        assert_eq!(find("microflow_autoscale_decisions_total", "action", "up"), 2.0);
        assert_eq!(find("microflow_autoscale_decisions_total", "action", "down"), 0.0);
        assert_eq!(find("microflow_span_events_total", "phase", "admit"), 6.0);
        // profile rows are cumulative, so the latest replaces wholesale
        assert_eq!(find("microflow_step_invocations_total", "step", "0"), 5.0);
        assert_eq!(find("microflow_step_ns_total", "step", "1"), 200.0);
        assert_eq!(find("microflow_replicas", "pool", "p"), 2.0);
        assert_eq!(find("microflow_breaker_state", "pool", "p"), 0.0);
    }

    #[test]
    fn help_and_type_appear_once_per_family() {
        let expo = Exposition::new();
        expo.absorb_tick(&[report("a"), report("b")]);
        let text = expo.render();
        for family in ["microflow_requests_total", "microflow_span_events_total"] {
            let help = text.matches(&format!("# HELP {family} ")).count();
            let kind = text.matches(&format!("# TYPE {family} ")).count();
            assert_eq!((help, kind), (1, 1), "{family}");
        }
        // pools render in sorted order: "a" samples precede "b" samples
        let a = text.find("pool=\"a\"").unwrap();
        let b = text.find("pool=\"b\"").unwrap();
        assert!(a < b);
    }

    #[test]
    fn stream_counters_surface_with_the_identity() {
        use crate::coordinator::stream::{StreamCounters, StreamSnapshot};
        let expo = Exposition::new();
        let snap = StreamHostSnapshot {
            streams: vec![StreamSnapshot {
                id: 1,
                name: "s".into(),
                worker: "stream-w0".into(),
                counters: StreamCounters {
                    submitted: 10,
                    completed: 7,
                    shed: 1,
                    cancelled: 1,
                    failed: 1,
                    verdicts: 2,
                },
            }],
            workers: Vec::new(),
        };
        expo.absorb_streams("kws", &snap);
        let samples = parse_exposition(&expo.render());
        let get = |outcome: &str| {
            samples
                .iter()
                .find(|s| {
                    s.name == "microflow_stream_pushes_total" && s.label("outcome") == Some(outcome)
                })
                .map(|s| s.value)
                .unwrap()
        };
        assert_eq!(get("completed") + get("shed") + get("cancelled") + get("failed"), get("submitted"));
        let v = samples
            .iter()
            .find(|s| s.name == "microflow_stream_verdicts_total")
            .unwrap();
        assert_eq!(v.label("model"), Some("kws"));
        assert_eq!(v.value, 2.0);
    }

    #[test]
    fn metrics_server_answers_a_raw_scrape() {
        let expo = Arc::new(Exposition::new());
        expo.absorb_tick(&[report("p")]);
        let server = MetricsServer::start("127.0.0.1:0", Arc::clone(&expo)).unwrap();
        let addr = server.local_addr();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        conn.write_all(b"GET /metrics HTTP/1.0\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK"), "{resp}");
        assert!(resp.contains("text/plain; version=0.0.4"));
        let body = resp.split("\r\n\r\n").nth(1).unwrap();
        assert!(parse_exposition(body)
            .iter()
            .any(|s| s.name == "microflow_requests_total"));
        server.shutdown();
    }
}
