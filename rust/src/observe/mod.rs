//! Zero-allocation observability plane (DESIGN: ISSUE 10).
//!
//! Three tiers, strictly layered so observation never perturbs serving:
//!
//! 1. **Span recorder** ([`span`]) — preallocated fixed-capacity ring
//!    buffers of POD span events covering the request lifecycle
//!    (admit → queue → batch → execute → reply). Recording is
//!    allocation-free, lock-free and wait-free; a full ring overwrites
//!    oldest-first and the loss is counted, never silent.
//! 2. **Per-step kernel profiles** ([`profile`]) — the [`StepObserver`]
//!    hook threaded through `engine::run_plan_from`, with fixed-table
//!    accumulators for single sessions ([`StepProfiler`]) and whole
//!    worker pools ([`SharedStepProfile`]).
//! 3. **Exposition** ([`expo`]) — the Prometheus-text snapshot assembled
//!    only from windows the tick loop already drained, served over
//!    `microflow serve --metrics-addr`, the version-agnostic `STAT` wire
//!    op, and the `microflow top` view.
//!
//! **The read-only invariant**: no policy decision may read a span ring,
//! and exporters only consume drained windows. The tick loop is the
//! single drain point — the same place that consumes `Metrics::window` —
//! so adding observability changes no control-loop behavior and no
//! serving result.

pub mod expo;
pub mod profile;
pub mod span;

pub use expo::{escape_label, parse_exposition, Exposition, MetricsServer, Sample};
pub use profile::{
    SharedProfileObserver, SharedStepProfile, StepObserver, StepProfileRow, StepProfiler, StepStat,
    MAX_STEPS,
};
pub use span::{
    Phase, SpanRecorder, SpanRing, SpanWindow, CLASS_LANES, PHASE_COUNT, SPAN_RING_CAPACITY,
};
