//! Per-step kernel profiles: a reusable [`StepObserver`] hook threaded
//! through `engine::run_plan_from`, plus two accumulator flavors —
//! [`StepProfiler`] (single-threaded, plain counters, for `audit
//! --profile` and benches) and [`SharedStepProfile`] (atomic counters a
//! whole worker pool can feed, drained by the tick loop).
//!
//! Both accumulate into fixed `[_; MAX_STEPS]` tables sized at compile
//! time, TFLM-style op profiling without its heap: attaching a profiler
//! to a session adds two `Instant` reads and two integer adds per plan
//! step and allocates nothing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Per-step hook invoked by `engine::run_plan_from` around each plan
/// step. `on_step_start` fires before the kernel runs; `on_step` fires
/// after, with the step's output view (pre-flip scratch).
///
/// A blanket impl keeps plain `FnMut(usize, &[i8])` closures (the
/// original `stream::prime()` observer shape) working unchanged — they
/// simply never see `on_step_start`.
pub trait StepObserver {
    /// Called immediately before step `step` executes.
    fn on_step_start(&mut self, _step: usize) {}
    /// Called after step `step` produced `out` (its quantized output).
    fn on_step(&mut self, step: usize, out: &[i8]);
}

impl<F: FnMut(usize, &[i8])> StepObserver for F {
    fn on_step(&mut self, step: usize, out: &[i8]) {
        self(step, out)
    }
}

/// Maximum plan steps a profile table covers. Steps beyond this are
/// counted in `overflow` instead of silently ignored. Every model the
/// compiler or `synth` currently emits fits comfortably.
pub const MAX_STEPS: usize = 64;

/// Accumulated timing for one plan step.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StepStat {
    /// Times the step executed.
    pub invocations: u64,
    /// Total wall-clock nanoseconds across all invocations.
    pub total_ns: u64,
}

impl StepStat {
    /// Mean nanoseconds per invocation (0 when never invoked).
    pub fn ns_per_call(&self) -> u64 {
        if self.invocations == 0 { 0 } else { self.total_ns / self.invocations }
    }
}

/// One exported profile row: a step index paired with its kind name and
/// cumulative counters. The exposition tier and `audit --profile` both
/// render these.
#[derive(Clone, Debug)]
pub struct StepProfileRow {
    pub step: usize,
    pub kind: &'static str,
    pub invocations: u64,
    pub total_ns: u64,
}

impl StepProfileRow {
    pub fn ns_per_call(&self) -> u64 {
        if self.invocations == 0 { 0 } else { self.total_ns / self.invocations }
    }
}

/// Single-threaded per-step profiler: a fixed `[StepStat; MAX_STEPS]`
/// table fed through the [`StepObserver`] hook. No allocation after
/// construction; safe to attach on the allocation-free predict path.
pub struct StepProfiler {
    stats: [StepStat; MAX_STEPS],
    pending: Option<(usize, Instant)>,
    overflow: u64,
}

impl StepProfiler {
    pub fn new() -> StepProfiler {
        StepProfiler { stats: [StepStat::default(); MAX_STEPS], pending: None, overflow: 0 }
    }

    /// The full fixed-size table (unused tail entries are zero).
    pub fn stats(&self) -> &[StepStat; MAX_STEPS] {
        &self.stats
    }

    /// One step's accumulated stat (`None` beyond [`MAX_STEPS`]).
    pub fn stat(&self, step: usize) -> Option<StepStat> {
        self.stats.get(step).copied()
    }

    /// Invocations of steps at index `>= MAX_STEPS` (not timed).
    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Number of leading table entries that have been invoked at least
    /// once — for a full plan run this equals the plan's step count.
    pub fn observed_steps(&self) -> usize {
        self.stats.iter().rposition(|s| s.invocations > 0).map_or(0, |i| i + 1)
    }

    /// Zero the table and overflow counter.
    pub fn reset(&mut self) {
        self.stats = [StepStat::default(); MAX_STEPS];
        self.pending = None;
        self.overflow = 0;
    }

    /// Export one row per entry of `kinds` (the session's
    /// `step_kinds()`), so rows cover every plan step exactly once even
    /// when a step was never invoked.
    pub fn rows(&self, kinds: &[&'static str]) -> Vec<StepProfileRow> {
        kinds
            .iter()
            .enumerate()
            .map(|(i, kind)| {
                let s = self.stat(i).unwrap_or_default();
                StepProfileRow { step: i, kind, invocations: s.invocations, total_ns: s.total_ns }
            })
            .collect()
    }
}

impl Default for StepProfiler {
    fn default() -> Self {
        StepProfiler::new()
    }
}

impl StepObserver for StepProfiler {
    fn on_step_start(&mut self, step: usize) {
        self.pending = Some((step, Instant::now()));
    }

    fn on_step(&mut self, step: usize, _out: &[i8]) {
        let ns = match self.pending.take() {
            Some((s, t0)) if s == step => t0.elapsed().as_nanos() as u64,
            _ => 0,
        };
        if let Some(stat) = self.stats.get_mut(step) {
            stat.invocations += 1;
            stat.total_ns += ns;
        } else {
            self.overflow += 1;
        }
    }
}

/// Pool-shared per-step profile: the same fixed table, but atomic, so
/// every worker in a `coordinator` pool can feed one instance through a
/// [`SharedProfileObserver`] without locks. Read by the tick loop via
/// [`SharedStepProfile::rows`] (cumulative counters — the exposition
/// tier exports them as Prometheus counters directly).
pub struct SharedStepProfile {
    invocations: [AtomicU64; MAX_STEPS],
    total_ns: [AtomicU64; MAX_STEPS],
    overflow: AtomicU64,
}

impl SharedStepProfile {
    pub fn new() -> SharedStepProfile {
        SharedStepProfile {
            invocations: std::array::from_fn(|_| AtomicU64::new(0)),
            total_ns: std::array::from_fn(|_| AtomicU64::new(0)),
            overflow: AtomicU64::new(0),
        }
    }

    /// Fold one timed step execution into the table. Lock-free,
    /// allocation-free.
    pub fn record(&self, step: usize, ns: u64) {
        if step < MAX_STEPS {
            self.invocations[step].fetch_add(1, Ordering::Relaxed);
            self.total_ns[step].fetch_add(ns, Ordering::Relaxed);
        } else {
            self.overflow.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn overflow(&self) -> u64 {
        self.overflow.load(Ordering::Relaxed)
    }

    /// Cumulative rows, one per entry of `kinds` (the pool's plan step
    /// kinds) — every plan step appears exactly once.
    pub fn rows(&self, kinds: &[&'static str]) -> Vec<StepProfileRow> {
        kinds
            .iter()
            .enumerate()
            .map(|(i, kind)| StepProfileRow {
                step: i,
                kind,
                invocations: if i < MAX_STEPS { self.invocations[i].load(Ordering::Relaxed) } else { 0 },
                total_ns: if i < MAX_STEPS { self.total_ns[i].load(Ordering::Relaxed) } else { 0 },
            })
            .collect()
    }
}

impl Default for SharedStepProfile {
    fn default() -> Self {
        SharedStepProfile::new()
    }
}

/// Per-batch adapter a worker stack-allocates to feed a
/// [`SharedStepProfile`]: times each step locally, publishes with one
/// `fetch_add` pair per step.
pub struct SharedProfileObserver<'a> {
    shared: &'a SharedStepProfile,
    pending: Option<(usize, Instant)>,
}

impl<'a> SharedProfileObserver<'a> {
    pub fn new(shared: &'a SharedStepProfile) -> SharedProfileObserver<'a> {
        SharedProfileObserver { shared, pending: None }
    }
}

impl StepObserver for SharedProfileObserver<'_> {
    fn on_step_start(&mut self, step: usize) {
        self.pending = Some((step, Instant::now()));
    }

    fn on_step(&mut self, step: usize, _out: &[i8]) {
        let ns = match self.pending.take() {
            Some((s, t0)) if s == step => t0.elapsed().as_nanos() as u64,
            _ => 0,
        };
        self.shared.record(step, ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closure_observers_still_satisfy_the_trait() {
        let mut seen = Vec::new();
        let mut cb = |i: usize, out: &[i8]| seen.push((i, out.len()));
        let obs: &mut dyn StepObserver = &mut cb;
        obs.on_step_start(0); // default no-op for closures
        obs.on_step(0, &[1, 2]);
        obs.on_step(1, &[3]);
        assert_eq!(seen, vec![(0, 2), (1, 1)]);
    }

    #[test]
    fn profiler_accumulates_invocations_and_time() {
        let mut p = StepProfiler::new();
        for _ in 0..3 {
            p.on_step_start(0);
            p.on_step(0, &[0]);
            p.on_step_start(1);
            p.on_step(1, &[0]);
        }
        assert_eq!(p.stat(0).unwrap().invocations, 3);
        assert_eq!(p.stat(1).unwrap().invocations, 3);
        assert_eq!(p.observed_steps(), 2);
        assert_eq!(p.overflow(), 0);
        p.reset();
        assert_eq!(p.observed_steps(), 0);
    }

    #[test]
    fn overflow_steps_are_counted_not_dropped() {
        let mut p = StepProfiler::new();
        p.on_step_start(MAX_STEPS + 3);
        p.on_step(MAX_STEPS + 3, &[0]);
        assert_eq!(p.overflow(), 1);
        assert_eq!(p.observed_steps(), 0);
    }

    #[test]
    fn mismatched_start_records_zero_time_not_garbage() {
        let mut p = StepProfiler::new();
        p.on_step_start(0);
        p.on_step(1, &[0]); // start/step mismatch: count it, time it 0
        assert_eq!(p.stat(1).unwrap().invocations, 1);
        assert_eq!(p.stat(1).unwrap().total_ns, 0);
    }

    #[test]
    fn rows_cover_every_kind_exactly_once() {
        let mut p = StepProfiler::new();
        p.on_step_start(0);
        p.on_step(0, &[0]);
        let rows = p.rows(&["FullyConnected", "Relu", "Softmax"]);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows.iter().map(|r| r.step).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(rows[0].invocations, 1);
        assert_eq!(rows[1].invocations, 0);
        assert_eq!(rows[2].kind, "Softmax");
    }

    #[test]
    fn shared_profile_merges_across_threads() {
        let shared = std::sync::Arc::new(SharedStepProfile::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let shared = std::sync::Arc::clone(&shared);
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    let mut obs = SharedProfileObserver::new(&shared);
                    obs.on_step_start(2);
                    obs.on_step(2, &[0]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let rows = shared.rows(&["A", "B", "C"]);
        assert_eq!(rows[2].invocations, 400);
        assert_eq!(rows[0].invocations, 0);
        assert_eq!(shared.overflow(), 0);
    }
}
