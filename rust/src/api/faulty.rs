//! Deterministic, seeded fault injection for the serving stack.
//!
//! A [`FaultPlan`] wraps any [`InferenceSession`] in a [`FaultySession`]
//! that fails on a schedule fixed entirely by the plan and its seed — no
//! wall clock, no OS entropy — so a chaos run replays bit-for-bit from
//! one seed. Three fault shapes cover the replica failure taxonomy the
//! coordinator defends against:
//!
//! * **error-on-Nth-call** ([`FaultPlan::transient_every`]) — the call
//!   fails with a [`FailureKind::Transient`] [`InjectedFault`]; the next
//!   call succeeds again. Models a flaky replica (bit flips, transient
//!   bus errors) that deadline-budgeted retry should absorb.
//! * **wedge-forever** ([`FaultPlan::wedge_after`]) — every call after
//!   the trigger fails, forever. The replica never recovers on its own;
//!   only health-driven ejection heals the pool. (A wedge fails fast
//!   rather than blocking: a worker blocked forever could never drain,
//!   so "wedged" means *permanently failing*, which the health counters
//!   observe as an unbroken consecutive-failure run.)
//! * **fatal-on-call** ([`FaultPlan::fatal_on`]) — one call fails with
//!   [`FailureKind::Fatal`]: the worker thread holding the session
//!   treats the replica as dead and exits, and the pool floor is
//!   restored by the autoscaler's warm below-min repair.
//!
//! Latency spikes ([`FaultPlan::spike_every`]) advance a **virtual tick**
//! counter instead of sleeping, keeping tests deterministic; an optional
//! real [`FaultPlan::tick_duration`] converts ticks to wall time for
//! latency-oriented benches. The module is test/bench-oriented but
//! compiled unconditionally: the chaos harness is a first-class part of
//! the serving surface, not a `#[cfg(test)]` afterthought.

use std::fmt;
use std::time::Duration;

use anyhow::Result;

use crate::api::{Engine, InferenceSession, IoSignature, Session};

/// How a replica failure should be treated by the serving layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FailureKind {
    /// The call failed but the replica is still usable: the request may
    /// be redispatched (within its retry budget and deadline) and the
    /// replica stays in the pool unless its health counters trip.
    Transient,
    /// The replica itself is gone: the worker exits, nothing on it is
    /// retried against it, and the pool heals by warm re-provisioning.
    Fatal,
}

impl FailureKind {
    pub fn name(self) -> &'static str {
        match self {
            FailureKind::Transient => "transient",
            FailureKind::Fatal => "fatal",
        }
    }
}

impl fmt::Display for FailureKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Typed error produced by a [`FaultySession`]. The coordinator's worker
/// classifies batch failures by downcasting to this type; any error that
/// is *not* an `InjectedFault` (a real engine failure) is treated as
/// [`FailureKind::Transient`] and bounded by the retry budget.
#[derive(Debug, Clone)]
pub struct InjectedFault {
    pub kind: FailureKind,
    /// 1-indexed call number at which the fault fired.
    pub call: u64,
    /// True when produced by the wedge schedule (permanently failing).
    pub wedged: bool,
}

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected {} fault at call {}", self.kind, self.call)?;
        if self.wedged {
            f.write_str(" (replica wedged)")?;
        }
        Ok(())
    }
}

impl std::error::Error for InjectedFault {}

/// A deterministic fault schedule. All schedules compose; precedence per
/// call is fatal → wedge → transient → spike (at most one fault fires).
///
/// The seed phase-shifts the periodic schedules so replicas sharing one
/// plan template but different seeds fail on *different* calls — a fleet
/// chaos run exercises staggered, not synchronized, failures.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    seed: u64,
    transient_every: Option<u64>,
    wedge_after: Option<u64>,
    fatal_on: Option<u64>,
    spike_every: Option<u64>,
    spike_ticks: u64,
    tick: Duration,
}

impl FaultPlan {
    /// A plan with no faults scheduled (wrap is then a pass-through that
    /// still counts calls/ticks — useful as a probe).
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::default() }
    }

    /// Fail every `n`-th call transiently (1-indexed, phase-shifted by
    /// the seed: call `c` fails when `(c + seed) % n == 0`).
    pub fn transient_every(mut self, n: u64) -> Self {
        self.transient_every = Some(n.max(1));
        self
    }

    /// Every call after the first `n` fails, forever (the replica is
    /// wedged; only ejection removes it from service).
    pub fn wedge_after(mut self, n: u64) -> Self {
        self.wedge_after = Some(n);
        self
    }

    /// Call `n` (1-indexed) fails with [`FailureKind::Fatal`] — the
    /// worker holding this session treats the replica as dead.
    pub fn fatal_on(mut self, n: u64) -> Self {
        self.fatal_on = Some(n.max(1));
        self
    }

    /// Every `n`-th call stalls for `ticks` virtual ticks before
    /// executing (phase-shifted by the seed like `transient_every`).
    pub fn spike_every(mut self, n: u64, ticks: u64) -> Self {
        self.spike_every = Some(n.max(1));
        self.spike_ticks = ticks;
        self
    }

    /// Real duration of one virtual tick (default zero: spikes advance
    /// the tick counter only, keeping tests fast and deterministic).
    pub fn tick_duration(mut self, d: Duration) -> Self {
        self.tick = d;
        self
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Wrap a session in this plan, preserving its label (so health
    /// accounting and `ReplicaError`s name the replica, not the wrapper).
    pub fn wrap(self, inner: Session) -> Session {
        let label = inner.label().to_string();
        Session::from_impl(Box::new(FaultySession::new(inner, self))).with_label(label)
    }

    /// Which fault (if any) fires on 1-indexed call `call`.
    fn fault_at(&self, call: u64) -> Option<InjectedFault> {
        if self.fatal_on == Some(call) {
            return Some(InjectedFault { kind: FailureKind::Fatal, call, wedged: false });
        }
        if let Some(after) = self.wedge_after {
            if call > after {
                return Some(InjectedFault { kind: FailureKind::Transient, call, wedged: true });
            }
        }
        if let Some(n) = self.transient_every {
            if (call.wrapping_add(self.seed)) % n == 0 {
                return Some(InjectedFault { kind: FailureKind::Transient, call, wedged: false });
            }
        }
        None
    }

    /// Virtual ticks the spike schedule charges on call `call`.
    fn spike_at(&self, call: u64) -> u64 {
        match self.spike_every {
            Some(n) if (call.wrapping_add(self.seed)) % n == 0 => self.spike_ticks,
            _ => 0,
        }
    }
}

/// An [`InferenceSession`] that executes its inner session except where
/// its [`FaultPlan`] schedules a fault. Batch calls count as ONE call:
/// faults model the replica, not individual samples, so a failing call
/// fails the whole batch exactly as a real replica fault would.
pub struct FaultySession {
    inner: Session,
    plan: FaultPlan,
    calls: u64,
    virtual_ticks: u64,
}

impl FaultySession {
    pub fn new(inner: Session, plan: FaultPlan) -> FaultySession {
        FaultySession { inner, plan, calls: 0, virtual_ticks: 0 }
    }

    /// Calls attempted so far (including faulted ones).
    pub fn calls(&self) -> u64 {
        self.calls
    }

    /// Virtual ticks accumulated by latency spikes.
    pub fn virtual_ticks(&self) -> u64 {
        self.virtual_ticks
    }

    /// Advance the call counter and fire the scheduled fault, if any.
    fn gate(&mut self) -> Result<()> {
        self.calls += 1;
        let spike = self.plan.spike_at(self.calls);
        if spike > 0 {
            self.virtual_ticks += spike;
            if !self.plan.tick.is_zero() {
                std::thread::sleep(self.plan.tick * spike.min(u32::MAX as u64) as u32);
            }
        }
        match self.plan.fault_at(self.calls) {
            Some(fault) => Err(fault.into()),
            None => Ok(()),
        }
    }
}

impl InferenceSession for FaultySession {
    fn engine(&self) -> Engine {
        self.inner.engine()
    }

    fn signature(&self) -> &IoSignature {
        self.inner.signature()
    }

    fn preferred_batch(&self) -> usize {
        self.inner.preferred_batch()
    }

    fn run_into(&mut self, input: &[i8], out: &mut [i8]) -> Result<()> {
        self.gate()?;
        self.inner.run_into(input, out)
    }

    fn run_batch_into(&mut self, inputs: &[i8], n: usize, out: &mut [i8]) -> Result<()> {
        self.gate()?;
        self.inner.run_batch_into(inputs, n, out)
    }

    fn buffer_ptrs(&self) -> Vec<usize> {
        self.inner.buffer_ptrs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth;
    use crate::util::Prng;

    fn base_session() -> (Session, Vec<i8>, Vec<i8>) {
        let mut rng = Prng::new(0xFA_017);
        let m = synth::fc_chain(&mut rng, &[4, 8, 3]);
        let mut s = Session::builder(&m).engine(Engine::MicroFlow).label("native/0").build().unwrap();
        let x = rng.i8_vec(4);
        let y = s.run(&x).unwrap();
        (s, x, y)
    }

    #[test]
    fn healthy_plan_is_a_labeled_passthrough() {
        let (s, x, y) = base_session();
        let mut wrapped = FaultPlan::new(7).wrap(s);
        assert_eq!(wrapped.label(), "native/0", "wrap must preserve the replica label");
        for _ in 0..5 {
            assert_eq!(wrapped.run(&x).unwrap(), y, "pass-through must stay bit-exact");
        }
    }

    #[test]
    fn transient_schedule_fails_exactly_every_nth_call() {
        let (s, x, _) = base_session();
        // seed 0: calls 3, 6, 9, ... fail
        let mut wrapped = FaultPlan::new(0).transient_every(3).wrap(s);
        let mut outcomes = Vec::new();
        for _ in 0..9 {
            outcomes.push(wrapped.run(&x).is_ok());
        }
        assert_eq!(outcomes, [true, true, false, true, true, false, true, true, false]);
    }

    #[test]
    fn seed_phase_shifts_the_schedule() {
        let (s, x, _) = base_session();
        // seed 1: (c + 1) % 3 == 0 -> calls 2, 5, 8 fail
        let mut wrapped = FaultPlan::new(1).transient_every(3).wrap(s);
        let outcomes: Vec<bool> = (0..6).map(|_| wrapped.run(&x).is_ok()).collect();
        assert_eq!(outcomes, [true, false, true, true, false, true]);
    }

    #[test]
    fn wedge_fails_forever_after_trigger() {
        let (s, x, y) = base_session();
        let mut wrapped = FaultPlan::new(0).wedge_after(2).wrap(s);
        assert_eq!(wrapped.run(&x).unwrap(), y);
        assert_eq!(wrapped.run(&x).unwrap(), y);
        for call in 3..10u64 {
            let err = wrapped.run(&x).unwrap_err();
            let fault = err.downcast_ref::<InjectedFault>().expect("typed fault");
            assert_eq!((fault.kind, fault.wedged, fault.call), (FailureKind::Transient, true, call));
        }
    }

    #[test]
    fn fatal_fires_once_with_fatal_kind() {
        let (s, x, _) = base_session();
        let mut wrapped = FaultPlan::new(0).fatal_on(2).wrap(s);
        assert!(wrapped.run(&x).is_ok());
        let err = wrapped.run(&x).unwrap_err();
        assert_eq!(err.downcast_ref::<InjectedFault>().unwrap().kind, FailureKind::Fatal);
        // fatal is a point event in the schedule; the session object is
        // nominally usable after (the WORKER is what dies on Fatal)
        assert!(wrapped.run(&x).is_ok());
    }

    #[test]
    fn spikes_advance_virtual_ticks_without_wall_clock() {
        let (s, x, _) = base_session();
        let mut faulty = FaultySession::new(s, FaultPlan::new(0).spike_every(2, 5));
        let mut out = vec![0i8; 3];
        for _ in 0..6 {
            faulty.run_into(&x, &mut out).unwrap();
        }
        assert_eq!(faulty.calls(), 6);
        assert_eq!(faulty.virtual_ticks(), 15, "calls 2, 4, 6 spike 5 ticks each");
    }

    #[test]
    fn batch_counts_as_one_call() {
        let (s, x, _) = base_session();
        let mut faulty = FaultySession::new(s, FaultPlan::new(0).transient_every(2));
        let mut batch_in = x.clone();
        batch_in.extend_from_slice(&x);
        let mut out = vec![0i8; 6];
        assert!(faulty.run_batch_into(&batch_in, 2, &mut out).is_ok(), "call 1 clean");
        assert!(faulty.run_batch_into(&batch_in, 2, &mut out).is_err(), "call 2 faults whole batch");
        assert_eq!(faulty.calls(), 2);
    }
}
