//! Warm-session cache — amortize compiles across replica builds.
//!
//! A fleet builds many sessions over the *same* model (N replicas per
//! pool, pools per model, restarts). The expensive part of
//! `SessionBuilder::build` is everything before execution: reading the
//! container, parsing it, folding constants and planning memory. This
//! cache keys that work by a **content hash** of the container bytes
//! ([`ModelSource::content_hash`]), so repeated builds of the same model
//! reuse:
//!
//! * the compiled plan (`Arc<CompiledModel>`) for native sessions — every
//!   replica shares one folded-weights image, the host-side analogue of N
//!   cores streaming the same Flash;
//! * the container bytes (`Arc<Vec<u8>>`) for interpreter sessions — the
//!   interpreter still re-parses per session (that runtime parsing *is*
//!   the TFLM cost being modeled), but the bytes are read/serialized once.
//!
//! PJRT sessions are not cached: the XLA client/executable graph holds
//! `Rc` state that must stay owned by exactly one session (see the
//! `Send` note in `api::sessions`).

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::ModelSource;
use crate::compiler::plan::{CompileOptions, CompiledModel};
use crate::format::mfb::MfbModel;

/// FNV-1a 64-bit over the container bytes — stable, dependency-free, and
/// plenty for cache keying (collisions would need adversarial containers).
pub fn content_hash64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Shared warm cache; hand the same instance (via `Arc`) to every
/// `SessionBuilder` that should share compiled plans.
#[derive(Debug, Default)]
pub struct SessionCache {
    compiled: Mutex<HashMap<(u64, bool, bool), Arc<CompiledModel>>>,
    bytes: Mutex<HashMap<u64, Arc<Vec<u8>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl SessionCache {
    pub fn new() -> SessionCache {
        SessionCache::default()
    }

    /// Cache lookups that found an entry.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache lookups that had to do the work.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    fn record(&self, hit: bool) {
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// `(content hash, container bytes)` for `source`, keyed by hash.
    fn bytes_entry(&self, source: ModelSource) -> Result<(u64, Arc<Vec<u8>>)> {
        let bytes = source.into_bytes()?;
        let h = content_hash64(&bytes);
        let mut map = self.bytes.lock().unwrap();
        let (hit, arc) = match map.entry(h) {
            Entry::Occupied(e) => (true, Arc::clone(e.get())),
            Entry::Vacant(v) => (false, Arc::clone(v.insert(Arc::new(bytes)))),
        };
        drop(map);
        self.record(hit);
        Ok((h, arc))
    }

    /// Container bytes for `source`, keyed by content hash.
    pub(crate) fn cached_bytes(&self, source: ModelSource) -> Result<Arc<Vec<u8>>> {
        Ok(self.bytes_entry(source)?.1)
    }

    /// Compiled plan for `source` under the given paging/certify modes;
    /// compiles at most once per (content hash, paging, certify) triple.
    /// Certified and uncertified plans are distinct entries: a certified
    /// plan carries its `Certificate`, and a builder asking for
    /// certification must never be handed an unverified cached plan.
    pub(crate) fn compiled_plan(
        &self,
        source: ModelSource,
        paging: bool,
        certify: bool,
    ) -> Result<Arc<CompiledModel>> {
        let (h, bytes) = self.bytes_entry(source)?;
        if let Some(c) = self.compiled.lock().unwrap().get(&(h, paging, certify)) {
            self.record(true);
            return Ok(Arc::clone(c));
        }
        // compile outside the lock (it can be seconds for big models);
        // a racing builder may compile too — last insert wins, both valid
        let model = MfbModel::parse(&bytes)?;
        let compiled = Arc::new(CompiledModel::compile(&model, CompileOptions { paging, certify })?);
        self.compiled.lock().unwrap().insert((h, paging, certify), Arc::clone(&compiled));
        self.record(false);
        Ok(compiled)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Engine, Session};
    use crate::format::mfb::tests::tiny_mfb;

    #[test]
    fn fnv_is_stable_and_input_sensitive() {
        let a = content_hash64(b"microflow");
        assert_eq!(a, content_hash64(b"microflow"));
        assert_ne!(a, content_hash64(b"microflou"));
        assert_ne!(content_hash64(b""), content_hash64(b"\0"));
    }

    #[test]
    fn native_replicas_share_one_compiled_plan() {
        let cache = Arc::new(SessionCache::new());
        let mut sessions: Vec<Session> = (0..4)
            .map(|_| {
                Session::builder(tiny_mfb())
                    .engine(Engine::MicroFlow)
                    .cache(&cache)
                    .build()
                    .unwrap()
            })
            .collect();
        // first build: bytes miss + compile miss; then 3x (bytes hit + plan hit)
        assert_eq!(cache.misses(), 2, "hits {} misses {}", cache.hits(), cache.misses());
        assert_eq!(cache.hits(), 6, "hits {} misses {}", cache.hits(), cache.misses());
        for s in &mut sessions {
            assert_eq!(s.run(&[3, 1]).unwrap(), vec![2, 0, 5]);
        }
    }

    #[test]
    fn paging_modes_are_cached_separately() {
        let cache = Arc::new(SessionCache::new());
        let mut a = Session::builder(tiny_mfb()).cache(&cache).build().unwrap();
        let mut b =
            Session::builder(tiny_mfb()).paging(true).cache(&cache).build().unwrap();
        // second build reuses the bytes but compiles its own paged plan
        assert_eq!(cache.misses(), 3);
        assert_eq!(a.run(&[3, 1]).unwrap(), b.run(&[3, 1]).unwrap());
    }

    #[test]
    fn certify_modes_are_cached_separately() {
        // an uncertified cached plan must never satisfy a certifying build
        let cache = Arc::new(SessionCache::new());
        let certified = cache.compiled_plan(tiny_mfb().into(), false, true).unwrap();
        let unchecked = cache.compiled_plan(tiny_mfb().into(), false, false).unwrap();
        assert!(certified.certificate.is_some());
        assert!(unchecked.certificate.is_none());
        assert_eq!(cache.misses(), 3); // bytes + two distinct compiles
        // and a repeat certifying build hits the certified entry
        let again = cache.compiled_plan(tiny_mfb().into(), false, true).unwrap();
        assert!(Arc::ptr_eq(&certified, &again));
    }

    #[test]
    fn interp_builds_reuse_the_container_bytes() {
        let cache = Arc::new(SessionCache::new());
        for _ in 0..3 {
            let mut s = Session::builder(tiny_mfb())
                .engine(Engine::Interp)
                .cache(&cache)
                .build()
                .unwrap();
            let out = s.run(&[3, 1]).unwrap();
            assert_eq!(out.len(), 3);
        }
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 2);
    }
}
