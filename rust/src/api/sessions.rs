//! The three [`InferenceSession`] implementations behind
//! [`Session::builder`](super::Session::builder).
//!
//! Each wraps one executor and adapts it to the uniform allocation-free
//! contract:
//!
//! * [`NativeSession`] — the MicroFlow engine: static ping-pong buffers,
//!   batch = per-sample loop over `predict_into`;
//! * [`InterpSession`] — the TFLM-like interpreter: tensor arena, batch =
//!   per-sample loop over `invoke_into`;
//! * [`PjrtSession`] — the AOT'd HLO on the XLA CPU client: true batched
//!   execution against the compiled batch variants.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use super::{check_batch, Engine, InferenceSession, IoSignature, DEFAULT_PREFERRED_BATCH};
use crate::compiler::plan::{CompileOptions, CompiledModel};
use crate::engine::MicroFlowEngine;
use crate::format::mfb::MfbModel;
use crate::interp::resolver::OpResolver;
use crate::interp::Interpreter;
use crate::runtime::PjrtEngine;

fn check_single(in_len: usize, out_len: usize, sig: &IoSignature) -> Result<()> {
    if in_len != sig.input_len() {
        bail!("input length {in_len} != model input {}", sig.input_len());
    }
    if out_len != sig.output_len() {
        bail!("output length {out_len} != model output {}", sig.output_len());
    }
    Ok(())
}

/// The native MicroFlow engine behind the session surface.
pub struct NativeSession {
    engine: MicroFlowEngine,
    signature: IoSignature,
    preferred_batch: usize,
}

impl NativeSession {
    pub(super) fn create(
        model: MfbModel,
        paging: bool,
        certify: bool,
        preferred_batch: Option<usize>,
    ) -> Result<NativeSession> {
        let signature = IoSignature::of_model(&model);
        let engine = MicroFlowEngine::new(&model, CompileOptions { paging, certify })?;
        Ok(NativeSession {
            engine,
            signature,
            preferred_batch: preferred_batch.unwrap_or(DEFAULT_PREFERRED_BATCH),
        })
    }

    /// Warm-cache path: reuse an already-compiled plan (shared via `Arc`,
    /// so replicas of the same model share one folded-weights image); only
    /// the per-session scratch buffers are allocated here.
    pub(super) fn from_compiled(
        compiled: Arc<CompiledModel>,
        preferred_batch: Option<usize>,
    ) -> NativeSession {
        let signature = IoSignature::of_compiled(&compiled);
        NativeSession {
            engine: MicroFlowEngine::from_compiled(compiled),
            signature,
            preferred_batch: preferred_batch.unwrap_or(DEFAULT_PREFERRED_BATCH),
        }
    }
}

impl InferenceSession for NativeSession {
    fn engine(&self) -> Engine {
        Engine::MicroFlow
    }

    fn signature(&self) -> &IoSignature {
        &self.signature
    }

    fn preferred_batch(&self) -> usize {
        self.preferred_batch
    }

    fn run_into(&mut self, input: &[i8], out: &mut [i8]) -> Result<()> {
        check_single(input.len(), out.len(), &self.signature)?;
        self.engine.predict_into(input, out);
        Ok(())
    }

    fn run_into_observed(
        &mut self,
        input: &[i8],
        out: &mut [i8],
        observer: &mut dyn crate::observe::StepObserver,
    ) -> Result<()> {
        check_single(input.len(), out.len(), &self.signature)?;
        self.engine.predict_into_observed(input, out, observer);
        Ok(())
    }

    fn step_kinds(&self) -> Vec<&'static str> {
        self.engine.compiled().steps.iter().map(|s| s.kind.name()).collect()
    }

    fn buffer_ptrs(&self) -> Vec<usize> {
        self.engine.buffer_ptrs()
    }
}

/// The TFLM-like interpreter behind the session surface.
pub struct InterpSession {
    interp: Interpreter,
    signature: IoSignature,
    preferred_batch: usize,
}

impl InterpSession {
    pub(super) fn create(bytes: &[u8], preferred_batch: Option<usize>) -> Result<InterpSession> {
        let interp = Interpreter::new(bytes, &OpResolver::with_all_kernels())?;
        let signature = IoSignature::of_model(interp.model());
        Ok(InterpSession {
            interp,
            signature,
            preferred_batch: preferred_batch.unwrap_or(DEFAULT_PREFERRED_BATCH),
        })
    }
}

impl InferenceSession for InterpSession {
    fn engine(&self) -> Engine {
        Engine::Interp
    }

    fn signature(&self) -> &IoSignature {
        &self.signature
    }

    fn preferred_batch(&self) -> usize {
        self.preferred_batch
    }

    fn run_into(&mut self, input: &[i8], out: &mut [i8]) -> Result<()> {
        check_single(input.len(), out.len(), &self.signature)?;
        self.interp.invoke_into(input, out)
    }

    fn buffer_ptrs(&self) -> Vec<usize> {
        let (arena, scratch) = self.interp.buffer_ptrs();
        vec![arena, scratch]
    }
}

/// The PJRT (JAX-AOT'd HLO) runtime behind the session surface.
pub struct PjrtSession {
    engine: PjrtEngine,
    signature: IoSignature,
    preferred_batch: usize,
}

// SAFETY: the xla crate's client/executable handles hold `Rc`s, making the
// type !Send by default. A `PjrtSession` owns its client AND every
// executable holding clones of that `Rc`; the whole object graph moves to
// exactly one worker thread at `Server::start` and is never aliased across
// threads afterwards (each worker owns its session exclusively; the trait
// takes `&mut self`). This is the crate's single `#![deny(unsafe_code)]`
// exemption.
#[allow(unsafe_code)]
unsafe impl Send for PjrtSession {}

impl PjrtSession {
    /// `model` is the caller's [`ModelSource`](super::ModelSource), parsed
    /// — the signature comes from it, and it must agree with the `.mfb`
    /// next to the HLO artifacts (the engine reads shapes/qparams there).
    pub(super) fn create(
        model: MfbModel,
        artifacts: &Path,
        name: &str,
        preferred_batch: Option<usize>,
    ) -> Result<PjrtSession> {
        let engine = PjrtEngine::load(artifacts, name)?;
        let signature = IoSignature::of_model(&model);
        ensure!(
            signature.input_len() == engine.input_len()
                && signature.output_len() == engine.output_len()
                && signature.input.qparams == engine.input_qparams
                && signature.output.qparams == engine.output_qparams,
            "model source disagrees with the PJRT artifacts for {name:?} in {}: \
             source {}x{} {:?}/{:?} vs artifacts {}x{} {:?}/{:?}",
            artifacts.display(),
            signature.input_len(),
            signature.output_len(),
            signature.input.qparams,
            signature.output.qparams,
            engine.input_len(),
            engine.output_len(),
            engine.input_qparams,
            engine.output_qparams,
        );
        let default_batch = engine.batch_sizes().last().copied().unwrap_or(1);
        Ok(PjrtSession {
            engine,
            signature,
            preferred_batch: preferred_batch.unwrap_or(default_batch),
        })
    }
}

impl InferenceSession for PjrtSession {
    fn engine(&self) -> Engine {
        Engine::Pjrt
    }

    fn signature(&self) -> &IoSignature {
        &self.signature
    }

    fn preferred_batch(&self) -> usize {
        self.preferred_batch
    }

    fn run_into(&mut self, input: &[i8], out: &mut [i8]) -> Result<()> {
        check_single(input.len(), out.len(), &self.signature)?;
        self.engine.execute_batch_into(input, 1, out)
    }

    /// True batched execution on the smallest AOT variant that fits.
    fn run_batch_into(&mut self, inputs: &[i8], n: usize, out: &mut [i8]) -> Result<()> {
        check_batch(inputs.len(), out.len(), n, self.signature.input_len(), self.signature.output_len())?;
        self.engine.execute_batch_into(inputs, n, out)
    }
}
