//! `microflow::api` — the crate's single public inference entry point.
//!
//! The reproduction grew three incompatible front doors: the native
//! MicroFlow engine (`MicroFlowEngine::new`), the TFLM-like interpreter
//! (`Interpreter::new`) and the PJRT runtime (`PjrtEngine::load`), each with
//! its own I/O conventions. This module unifies them behind one
//! session-based surface, the way TFLM exposes a single `MicroInterpreter`
//! regardless of which kernels end up linked in:
//!
//! ```no_run
//! use microflow::api::{Engine, Session};
//!
//! let mut session = Session::builder("artifacts/sine.mfb")
//!     .engine(Engine::MicroFlow)
//!     .paging(false)
//!     .preferred_batch(32)
//!     .build()?;
//! let sig = session.signature().clone();
//! let q = sig.input.qparams.quantize_slice(&[1.0]);
//! let out = session.run(&q)?;
//! # anyhow::Ok(())
//! ```
//!
//! Layers of the surface:
//!
//! * [`TensorSpec`] / [`IoSignature`] — shape + quantization of the model's
//!   endpoints, replacing the scattered `input_len()` / `input_qparams()`
//!   method quadruplets;
//! * [`ModelSource`] — where the model comes from: a path, raw MFB bytes,
//!   or an already-parsed [`MfbModel`];
//! * [`SessionBuilder`] — engine selection plus per-engine options
//!   (paging, preferred batch, PJRT artifact location) in one place;
//! * [`InferenceSession`] — the executor trait all three engines
//!   implement: allocation-free `run_into` / `run_batch_into` on the hot
//!   path, with allocating conveniences layered on top;
//! * [`Session`] — a boxed, engine-erased session; what the coordinator's
//!   worker pool, the CLI and the benches all hold;
//! * [`ReplicaFactory`] — a frozen replica recipe (source + engine +
//!   options + warm [`SessionCache`]) the elastic serving tier provisions
//!   scale-up sessions from without recompiling;
//! * [`faulty`] — deterministic, seeded fault injection ([`FaultPlan`]
//!   wrapping any session): the chaos harness the fault-tolerance layer
//!   is tested against, compiled unconditionally;
//! * [`StreamSession`] (re-exported from [`crate::stream`]) — the
//!   stateful frame-at-a-time surface over the same engines:
//!   `push(frame) -> Option<verdict>` with a compiled, certified pulse
//!   schedule on the native path and any [`Session`] as replay oracle.
//!
//! The low-level constructors remain available for engine-internal work
//! (compilation introspection, the sim memory model), but every serving
//! path in the crate goes through this module.

mod cache;
mod factory;
pub mod faulty;
mod sessions;

pub use cache::{content_hash64, SessionCache};
pub use factory::ReplicaFactory;
pub use faulty::{FailureKind, FaultPlan, FaultySession, InjectedFault};
pub use sessions::{InterpSession, NativeSession, PjrtSession};

pub use crate::stream::{RingBuffer, StreamSession};

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::format::mfb::MfbModel;
use crate::tensor::quant::QParams;

/// Default preferred batch for the per-sample engines (native + interp).
/// PJRT defaults to its largest AOT-compiled batch variant instead.
pub const DEFAULT_PREFERRED_BATCH: usize = 8;

/// Which executor a session runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Engine {
    /// The paper's system: compile once, static buffers, folded constants.
    MicroFlow,
    /// The TFLM-like interpreter baseline: runtime parsing, tensor arena,
    /// per-node dispatch, fixed-point requantization.
    Interp,
    /// The JAX-AOT'd HLO executed by the XLA CPU client (true batched
    /// execution; requires the `pjrt` build feature and HLO artifacts).
    Pjrt,
}

impl Engine {
    /// Stable lowercase name (CLI values, metrics labels).
    pub fn name(self) -> &'static str {
        match self {
            Engine::MicroFlow => "microflow",
            Engine::Interp => "tflm-interp",
            Engine::Pjrt => "pjrt",
        }
    }
}

impl std::fmt::Display for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Engine {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "microflow" | "native" => Engine::MicroFlow,
            "tflm" | "interp" | "tflm-interp" => Engine::Interp,
            "pjrt" | "xla" => Engine::Pjrt,
            other => bail!("unknown engine {other:?} (microflow | tflm | pjrt)"),
        })
    }
}

/// Shape + quantization of one model endpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Per-sample dims (no batch dimension).
    pub shape: Vec<usize>,
    pub qparams: QParams,
}

impl TensorSpec {
    pub fn new(shape: Vec<usize>, qparams: QParams) -> Self {
        TensorSpec { shape, qparams }
    }

    /// Element count per sample.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Quantize a float sample with this endpoint's qparams.
    pub fn quantize(&self, r: &[f32]) -> Vec<i8> {
        self.qparams.quantize_slice(r)
    }

    /// Dequantize a quantized sample with this endpoint's qparams.
    pub fn dequantize(&self, q: &[i8]) -> Vec<f32> {
        q.iter().map(|&v| self.qparams.dequantize(v)).collect()
    }
}

/// A model's I/O contract: what goes in, what comes out.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSignature {
    pub input: TensorSpec,
    pub output: TensorSpec,
}

impl IoSignature {
    /// Read the signature off a parsed container (all engines agree on it
    /// — the MFB is the single source of truth for shapes and qparams).
    pub fn of_model(model: &MfbModel) -> IoSignature {
        IoSignature {
            input: TensorSpec::new(model.input_shape(), model.input_qparams()),
            output: TensorSpec::new(model.output_shape(), model.output_qparams()),
        }
    }

    /// Read the signature off a compiled plan (the same data the container
    /// carries, surviving compilation — the warm-cache path uses this).
    pub fn of_compiled(c: &crate::compiler::plan::CompiledModel) -> IoSignature {
        IoSignature {
            input: TensorSpec::new(c.input_shape.clone(), c.input_qparams),
            output: TensorSpec::new(c.output_shape.clone(), c.output_qparams),
        }
    }

    pub fn input_len(&self) -> usize {
        self.input.len()
    }

    pub fn output_len(&self) -> usize {
        self.output.len()
    }
}

/// Where a session's model comes from.
#[derive(Clone, Debug)]
pub enum ModelSource {
    /// A `.mfb` file on disk.
    Path(PathBuf),
    /// Raw MFB container bytes.
    Bytes(Vec<u8>),
    /// An already-parsed container.
    Parsed(MfbModel),
}

impl ModelSource {
    /// The container bytes (read, kept, or re-serialized as needed).
    fn into_bytes(self) -> Result<Vec<u8>> {
        Ok(match self {
            ModelSource::Path(p) => {
                std::fs::read(&p).with_context(|| format!("reading {}", p.display()))?
            }
            ModelSource::Bytes(b) => b,
            ModelSource::Parsed(m) => {
                crate::format::builder::serialize(&m).context("serializing parsed model")?
            }
        })
    }

    /// The parsed container.
    pub(crate) fn into_model(self) -> Result<MfbModel> {
        Ok(match self {
            ModelSource::Path(p) => MfbModel::load(&p)?,
            ModelSource::Bytes(b) => MfbModel::parse(&b)?,
            ModelSource::Parsed(m) => m,
        })
    }

    /// Content hash of the container bytes (FNV-1a 64) — the warm-cache
    /// key: two sources with the same serialized container hash equal
    /// regardless of where they came from.
    pub fn content_hash(&self) -> Result<u64> {
        Ok(match self {
            ModelSource::Path(p) => content_hash64(
                &std::fs::read(p).with_context(|| format!("reading {}", p.display()))?,
            ),
            ModelSource::Bytes(b) => content_hash64(b),
            ModelSource::Parsed(m) => content_hash64(
                &crate::format::builder::serialize(m).context("serializing parsed model")?,
            ),
        })
    }

    /// `(artifacts dir, model name)` for the PJRT loader, derivable only
    /// from a `<dir>/<name>.mfb` path.
    fn pjrt_location(&self) -> Option<(PathBuf, String)> {
        let ModelSource::Path(p) = self else { return None };
        let dir = p.parent()?.to_path_buf();
        let name = p.file_stem()?.to_str()?.to_string();
        Some((dir, name))
    }
}

impl From<PathBuf> for ModelSource {
    fn from(p: PathBuf) -> Self {
        ModelSource::Path(p)
    }
}

impl From<&Path> for ModelSource {
    fn from(p: &Path) -> Self {
        ModelSource::Path(p.to_path_buf())
    }
}

impl From<&PathBuf> for ModelSource {
    fn from(p: &PathBuf) -> Self {
        ModelSource::Path(p.clone())
    }
}

impl From<&str> for ModelSource {
    fn from(p: &str) -> Self {
        ModelSource::Path(p.into())
    }
}

impl From<Vec<u8>> for ModelSource {
    fn from(b: Vec<u8>) -> Self {
        ModelSource::Bytes(b)
    }
}

impl From<&[u8]> for ModelSource {
    fn from(b: &[u8]) -> Self {
        ModelSource::Bytes(b.to_vec())
    }
}

impl From<MfbModel> for ModelSource {
    fn from(m: MfbModel) -> Self {
        ModelSource::Parsed(m)
    }
}

/// Deep-clones the model, **including every weight payload** — convenient
/// for tests and small models; pass the `MfbModel` by value (or a path)
/// when the copy matters.
impl From<&MfbModel> for ModelSource {
    fn from(m: &MfbModel) -> Self {
        ModelSource::Parsed(m.clone())
    }
}

/// An executor for one loaded model.
///
/// The hot-path contract: `run_into` and `run_batch_into` never allocate
/// at all on the host engines — buffers (arena, ping-pong activations,
/// kernel scratch, i32 accumulators, staging) are plan-sized at build
/// time, asserted both by the pointer-stability conformance tests and by
/// the counting-allocator suite (`tests/alloc_free.rs`) — and write
/// results only into caller-provided slices. One exemption remains: the
/// PJRT implementation stages literals at the XLA FFI boundary. All three
/// engines implement this.
pub trait InferenceSession: Send {
    fn engine(&self) -> Engine;

    fn signature(&self) -> &IoSignature;

    /// Largest batch worth submitting at once (the dynamic batcher's
    /// target). Builder-configurable via
    /// [`SessionBuilder::preferred_batch`].
    fn preferred_batch(&self) -> usize;

    /// One quantized inference: int8 in, int8 out, written into `out`.
    fn run_into(&mut self, input: &[i8], out: &mut [i8]) -> Result<()>;

    /// Like [`InferenceSession::run_into`], with a per-step
    /// [`StepObserver`](crate::observe::StepObserver) attached — the
    /// profiling path. Engines with a step-granular executor (the native
    /// engine) override to fire the hooks around every plan step; the
    /// default just runs unobserved, so attaching a profiler to an
    /// opaque-executor engine (interp, PJRT) is valid but records nothing.
    fn run_into_observed(
        &mut self,
        input: &[i8],
        out: &mut [i8],
        _observer: &mut dyn crate::observe::StepObserver,
    ) -> Result<()> {
        self.run_into(input, out)
    }

    /// Batched [`InferenceSession::run_into_observed`]: the default loops
    /// the observed single-sample path, allocation-free by construction.
    fn run_batch_into_observed(
        &mut self,
        inputs: &[i8],
        n: usize,
        out: &mut [i8],
        observer: &mut dyn crate::observe::StepObserver,
    ) -> Result<()> {
        let (ilen, olen) = (self.signature().input_len(), self.signature().output_len());
        check_batch(inputs.len(), out.len(), n, ilen, olen)?;
        for i in 0..n {
            self.run_into_observed(
                &inputs[i * ilen..(i + 1) * ilen],
                &mut out[i * olen..(i + 1) * olen],
                observer,
            )?;
        }
        Ok(())
    }

    /// Stable kind names of the session's plan steps, in execution order
    /// (what per-step profile rows are labelled with). Engines without a
    /// step-granular plan return `[]`.
    fn step_kinds(&self) -> Vec<&'static str> {
        Vec::new()
    }

    /// Execute `n` samples packed in `inputs` (`n * input_len` values),
    /// writing `n * output_len` values into `out`.
    ///
    /// The default loops `run_into` over the samples — allocation-free by
    /// construction. Engines with native batch execution (PJRT) override.
    fn run_batch_into(&mut self, inputs: &[i8], n: usize, out: &mut [i8]) -> Result<()> {
        let (ilen, olen) = (self.signature().input_len(), self.signature().output_len());
        check_batch(inputs.len(), out.len(), n, ilen, olen)?;
        for i in 0..n {
            self.run_into(&inputs[i * ilen..(i + 1) * ilen], &mut out[i * olen..(i + 1) * olen])?;
        }
        Ok(())
    }

    /// Base addresses of the session's long-lived internal buffers, for
    /// pointer-stability tests (a changed address betrays a reallocation
    /// on the hot path). Engines without host-visible buffers return `[]`.
    fn buffer_ptrs(&self) -> Vec<usize> {
        Vec::new()
    }
}

/// Shared batch-shape validation for `run_batch_into` implementations.
pub(crate) fn check_batch(in_len: usize, out_len: usize, n: usize, ilen: usize, olen: usize) -> Result<()> {
    if in_len != n * ilen {
        bail!("batch input length {in_len} != n {n} * input_len {ilen}");
    }
    if out_len != n * olen {
        bail!("batch output length {out_len} != n {n} * output_len {olen}");
    }
    Ok(())
}

/// An engine-erased inference session — what the serving layers hold.
pub struct Session {
    inner: Box<dyn InferenceSession>,
    label: Option<String>,
}

impl Session {
    /// Start configuring a session over a model source.
    pub fn builder(source: impl Into<ModelSource>) -> SessionBuilder {
        SessionBuilder::new(source)
    }

    /// Wrap a custom [`InferenceSession`] implementation (new backends
    /// plug into the serving stack through this).
    pub fn from_impl(inner: Box<dyn InferenceSession>) -> Session {
        Session { inner, label: None }
    }

    /// Operator-assigned name (set via [`SessionBuilder::label`]) — shown
    /// in fleet metrics and debug output; defaults to the engine name.
    pub fn label(&self) -> &str {
        self.label.as_deref().unwrap_or_else(|| self.inner.engine().name())
    }

    /// Attach or replace the label after construction. Wrappers built
    /// through [`Session::from_impl`] (e.g. [`faulty::FaultPlan::wrap`])
    /// use this to keep the wrapped replica's identity.
    pub fn with_label(mut self, label: impl Into<String>) -> Session {
        self.label = Some(label.into());
        self
    }

    pub fn engine(&self) -> Engine {
        self.inner.engine()
    }

    pub fn signature(&self) -> &IoSignature {
        self.inner.signature()
    }

    pub fn input_len(&self) -> usize {
        self.inner.signature().input_len()
    }

    pub fn output_len(&self) -> usize {
        self.inner.signature().output_len()
    }

    pub fn input_qparams(&self) -> QParams {
        self.inner.signature().input.qparams
    }

    pub fn output_qparams(&self) -> QParams {
        self.inner.signature().output.qparams
    }

    pub fn preferred_batch(&self) -> usize {
        self.inner.preferred_batch()
    }

    /// Allocation-free single inference.
    pub fn run_into(&mut self, input: &[i8], out: &mut [i8]) -> Result<()> {
        self.inner.run_into(input, out)
    }

    /// Allocation-free batched inference (`n` packed samples).
    pub fn run_batch_into(&mut self, inputs: &[i8], n: usize, out: &mut [i8]) -> Result<()> {
        self.inner.run_batch_into(inputs, n, out)
    }

    /// Single inference with a per-step observer attached (see
    /// [`InferenceSession::run_into_observed`]). Still allocation-free.
    pub fn run_into_observed(
        &mut self,
        input: &[i8],
        out: &mut [i8],
        observer: &mut dyn crate::observe::StepObserver,
    ) -> Result<()> {
        self.inner.run_into_observed(input, out, observer)
    }

    /// Batched inference with a per-step observer attached.
    pub fn run_batch_into_observed(
        &mut self,
        inputs: &[i8],
        n: usize,
        out: &mut [i8],
        observer: &mut dyn crate::observe::StepObserver,
    ) -> Result<()> {
        self.inner.run_batch_into_observed(inputs, n, out, observer)
    }

    /// See [`InferenceSession::step_kinds`].
    pub fn step_kinds(&self) -> Vec<&'static str> {
        self.inner.step_kinds()
    }

    /// Single inference, allocating the output (convenience).
    pub fn run(&mut self, input: &[i8]) -> Result<Vec<i8>> {
        let mut out = vec![0i8; self.output_len()];
        self.inner.run_into(input, &mut out)?;
        Ok(out)
    }

    /// Batched inference, allocating the output (convenience).
    pub fn run_batch(&mut self, inputs: &[i8], n: usize) -> Result<Vec<i8>> {
        let mut out = vec![0i8; n * self.output_len()];
        self.inner.run_batch_into(inputs, n, &mut out)?;
        Ok(out)
    }

    /// Float convenience: quantize in, dequantize out with the model's
    /// endpoint qparams.
    pub fn run_f32(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        let q = self.input_qparams().quantize_slice(input);
        let out = self.run(&q)?;
        let oq = self.output_qparams();
        Ok(out.iter().map(|&v| oq.dequantize(v)).collect())
    }

    /// See [`InferenceSession::buffer_ptrs`].
    pub fn buffer_ptrs(&self) -> Vec<usize> {
        self.inner.buffer_ptrs()
    }
}

impl std::fmt::Debug for Session {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Session")
            .field("engine", &self.engine())
            .field("label", &self.label())
            .field("signature", self.signature())
            .finish()
    }
}

/// Configures and constructs a [`Session`].
///
/// Subsumes the three removed ad-hoc constructors and the bare
/// `CompileOptions { paging }` bool:
///
/// * `.engine(Engine::MicroFlow)` + `.paging(true)` — the paged native
///   executor (old `MicroFlowEngine::new(&m, CompileOptions { paging })`);
/// * `.engine(Engine::Interp)` — the TFLM-like interpreter (old
///   `Interpreter::new(&bytes, &OpResolver::with_all_kernels())`);
/// * `.engine(Engine::Pjrt)` — the AOT'd HLO runtime (old
///   `PjrtEngine::load(dir, name)`); the artifacts location is derived
///   from a `<dir>/<name>.mfb` path source or set explicitly with
///   [`SessionBuilder::pjrt_artifacts`].
#[derive(Debug)]
pub struct SessionBuilder {
    source: ModelSource,
    engine: Engine,
    paging: bool,
    certify: bool,
    preferred_batch: Option<usize>,
    pjrt_artifacts: Option<(PathBuf, String)>,
    label: Option<String>,
    cache: Option<Arc<SessionCache>>,
}

impl SessionBuilder {
    pub fn new(source: impl Into<ModelSource>) -> SessionBuilder {
        SessionBuilder {
            source: source.into(),
            engine: Engine::MicroFlow,
            paging: false,
            certify: true,
            preferred_batch: None,
            pjrt_artifacts: None,
            label: None,
            cache: None,
        }
    }

    /// Select the executor (default: [`Engine::MicroFlow`]).
    pub fn engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    /// Execute FullyConnected layers page-by-page (paper Sec. 4.3; native
    /// engine only — `build` rejects it for the other engines). Default:
    /// off.
    pub fn paging(mut self, paging: bool) -> Self {
        self.paging = paging;
        self
    }

    /// Statically certify the compiled plan (native engine only; see
    /// [`crate::compiler::verify`]): shape/packing soundness, an
    /// independent replay of the memory plan, and worst-case accumulator
    /// interval analysis. Default: **on** — pass `false` to skip the
    /// analysis (e.g. per-request compiles on a latency budget; the plan
    /// then carries no [`crate::compiler::Certificate`]).
    pub fn certify(mut self, certify: bool) -> Self {
        self.certify = certify;
        self
    }

    /// Override the batch size the session advertises to the dynamic
    /// batcher. Defaults: [`DEFAULT_PREFERRED_BATCH`] for the per-sample
    /// engines, the largest AOT batch variant for PJRT.
    pub fn preferred_batch(mut self, n: usize) -> Self {
        self.preferred_batch = Some(n.max(1));
        self
    }

    /// Explicit PJRT artifact location (`<dir>/<name>_quant_b*.hlo.txt`),
    /// for sources that aren't a `<dir>/<name>.mfb` path.
    pub fn pjrt_artifacts(mut self, dir: impl Into<PathBuf>, model: impl Into<String>) -> Self {
        self.pjrt_artifacts = Some((dir.into(), model.into()));
        self
    }

    /// Name the session (shown in fleet metrics and `Debug` output).
    pub fn label(mut self, label: impl Into<String>) -> Self {
        self.label = Some(label.into());
        self
    }

    /// Build through a warm [`SessionCache`]: native sessions reuse the
    /// compiled plan of any earlier build of the same container (keyed by
    /// [`ModelSource::content_hash`] + paging mode); interpreter sessions
    /// reuse the container bytes. PJRT sessions are never cached.
    pub fn cache(mut self, cache: &Arc<SessionCache>) -> Self {
        self.cache = Some(Arc::clone(cache));
        self
    }

    /// Construct the session: load/parse the model, run the selected
    /// engine's setup (compile / allocate-tensors / XLA compile), and
    /// box it behind the uniform surface.
    pub fn build(self) -> Result<Session> {
        let inner: Box<dyn InferenceSession> = match self.engine {
            Engine::MicroFlow => match &self.cache {
                Some(cache) => Box::new(NativeSession::from_compiled(
                    cache.compiled_plan(self.source, self.paging, self.certify)?,
                    self.preferred_batch,
                )),
                None => Box::new(NativeSession::create(
                    self.source.into_model()?,
                    self.paging,
                    self.certify,
                    self.preferred_batch,
                )?),
            },
            Engine::Interp => {
                if self.paging {
                    bail!("paging is a MicroFlow-engine option; the interpreter has no paged mode");
                }
                match &self.cache {
                    Some(cache) => Box::new(InterpSession::create(
                        &cache.cached_bytes(self.source)?,
                        self.preferred_batch,
                    )?),
                    None => Box::new(InterpSession::create(
                        &self.source.into_bytes()?,
                        self.preferred_batch,
                    )?),
                }
            }
            Engine::Pjrt => {
                if self.paging {
                    bail!("paging is a MicroFlow-engine option; PJRT executes the AOT'd HLO");
                }
                let (dir, name) = match self.pjrt_artifacts {
                    Some(loc) => loc,
                    None => self.source.pjrt_location().context(
                        "PJRT needs an artifacts location: pass a <dir>/<model>.mfb path \
                         source or call .pjrt_artifacts(dir, model)",
                    )?,
                };
                // the source supplies the signature (and is validated
                // against the artifacts' own container inside create)
                let model = self.source.into_model()?;
                Box::new(PjrtSession::create(model, &dir, &name, self.preferred_batch)?)
            }
        };
        Ok(Session { inner, label: self.label })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::mfb::tests::tiny_mfb;

    fn tiny_session(engine: Engine) -> Session {
        Session::builder(tiny_mfb()).engine(engine).build().unwrap()
    }

    #[test]
    fn engine_parses_cli_names() {
        assert_eq!("microflow".parse::<Engine>().unwrap(), Engine::MicroFlow);
        assert_eq!("tflm".parse::<Engine>().unwrap(), Engine::Interp);
        assert_eq!("pjrt".parse::<Engine>().unwrap(), Engine::Pjrt);
        assert!("mystery".parse::<Engine>().is_err());
    }

    #[test]
    fn signature_matches_the_container() {
        let s = tiny_session(Engine::MicroFlow);
        assert_eq!(s.signature().input.shape, vec![2]);
        assert_eq!(s.signature().output.shape, vec![3]);
        assert_eq!(s.input_len(), 2);
        assert_eq!(s.output_len(), 3);
        assert_eq!(s.input_qparams(), QParams::new(0.5, -1));
    }

    #[test]
    fn native_session_runs_the_tiny_model() {
        // same expectation as the engine unit test: FC + fused relu
        let mut s = tiny_session(Engine::MicroFlow);
        assert_eq!(s.run(&[3, 1]).unwrap(), vec![2, 0, 5]);
    }

    #[test]
    fn interp_session_agrees_within_one() {
        let mut nat = tiny_session(Engine::MicroFlow);
        let mut itp = tiny_session(Engine::Interp);
        assert_eq!(itp.engine(), Engine::Interp);
        for x in [[3i8, 1], [-5, 99], [127, -128]] {
            let a = nat.run(&x).unwrap();
            let b = itp.run(&x).unwrap();
            for (u, v) in a.iter().zip(&b) {
                assert!((*u as i32 - *v as i32).abs() <= 1, "{a:?} vs {b:?}");
            }
        }
    }

    #[test]
    fn paged_native_is_bit_identical() {
        let mut a = tiny_session(Engine::MicroFlow);
        let mut b = Session::builder(tiny_mfb())
            .engine(Engine::MicroFlow)
            .paging(true)
            .build()
            .unwrap();
        for x in [[0i8, 0], [127, -128], [-5, 99]] {
            assert_eq!(a.run(&x).unwrap(), b.run(&x).unwrap());
        }
    }

    #[test]
    fn parsed_source_round_trips_through_the_serializer() {
        let m = MfbModel::parse(&tiny_mfb()).unwrap();
        let mut s = Session::builder(&m).engine(Engine::Interp).build().unwrap();
        let out = s.run(&[3, 1]).unwrap();
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn preferred_batch_is_configurable() {
        let s = tiny_session(Engine::MicroFlow);
        assert_eq!(s.preferred_batch(), DEFAULT_PREFERRED_BATCH);
        let s = Session::builder(tiny_mfb()).preferred_batch(32).build().unwrap();
        assert_eq!(s.preferred_batch(), 32);
        let s = Session::builder(tiny_mfb()).engine(Engine::Interp).preferred_batch(3).build().unwrap();
        assert_eq!(s.preferred_batch(), 3);
    }

    #[test]
    fn run_batch_into_is_allocation_free() {
        // buffer pointers stable across repeated batched calls — the
        // static-allocation story extended to the batch path
        for engine in [Engine::MicroFlow, Engine::Interp] {
            let mut s = tiny_session(engine);
            let inputs: Vec<i8> = vec![3, 1, -5, 99, 0, 0, 7, -7];
            let mut out = vec![0i8; 4 * 3];
            s.run_batch_into(&inputs, 4, &mut out).unwrap();
            let p0 = s.buffer_ptrs();
            assert!(!p0.is_empty(), "{engine} exposes no buffers");
            for _ in 0..10 {
                s.run_batch_into(&inputs, 4, &mut out).unwrap();
            }
            assert_eq!(s.buffer_ptrs(), p0, "{engine} reallocated on the batch path");
        }
    }

    #[test]
    fn batch_results_match_single_runs() {
        for engine in [Engine::MicroFlow, Engine::Interp] {
            let mut s = tiny_session(engine);
            let inputs: Vec<i8> = vec![3, 1, -5, 99, 64, -64];
            let batched = s.run_batch(&inputs, 3).unwrap();
            for i in 0..3 {
                let single = s.run(&inputs[i * 2..(i + 1) * 2]).unwrap();
                assert_eq!(&batched[i * 3..(i + 1) * 3], single.as_slice(), "sample {i}");
            }
        }
    }

    #[test]
    fn shape_errors_are_results_not_panics() {
        let mut s = tiny_session(Engine::MicroFlow);
        assert!(s.run(&[1, 2, 3]).is_err());
        let mut out = vec![0i8; 2]; // wrong: output_len is 3
        assert!(s.run_into(&[1, 2], &mut out).is_err());
        let mut out = vec![0i8; 6];
        assert!(s.run_batch_into(&[1, 2, 3], 2, &mut out).is_err());
    }

    #[test]
    fn content_hash_is_source_independent() {
        // the same container hashes equal whether held as bytes or parsed
        let bytes = tiny_mfb();
        let parsed = MfbModel::parse(&bytes).unwrap();
        let h_bytes = ModelSource::from(bytes.clone()).content_hash().unwrap();
        let h_parsed = ModelSource::from(parsed).content_hash().unwrap();
        assert_eq!(h_bytes, h_parsed);
        let mut other = bytes;
        *other.last_mut().unwrap() ^= 1;
        assert_ne!(h_bytes, ModelSource::from(other).content_hash().unwrap());
    }

    #[test]
    fn label_defaults_to_engine_name() {
        let s = tiny_session(Engine::MicroFlow);
        assert_eq!(s.label(), "microflow");
        let s = Session::builder(tiny_mfb()).label("pool-a/0").build().unwrap();
        assert_eq!(s.label(), "pool-a/0");
        assert!(format!("{s:?}").contains("pool-a/0"));
    }

    #[test]
    fn interp_rejects_paging() {
        assert!(Session::builder(tiny_mfb()).engine(Engine::Interp).paging(true).build().is_err());
    }

    #[test]
    fn pjrt_without_location_is_a_clear_error() {
        let err = Session::builder(tiny_mfb()).engine(Engine::Pjrt).build().unwrap_err();
        assert!(format!("{err:#}").contains("artifacts"), "{err:#}");
    }
}
