//! Warm replica provisioning — the autoscaler's session supply.
//!
//! Scaling a pool up at runtime means building a new [`Session`] while
//! traffic is flowing; doing the full compile again per replica would
//! make scale-up latency proportional to model size. A [`ReplicaFactory`]
//! freezes one replica recipe (model source, engine, paging, preferred
//! batch) and provisions every new session through a shared
//! [`SessionCache`], so:
//!
//! * **native** replicas clone the shared `Arc<CompiledModel>` — scale-up
//!   costs no recompile, just plan-sized buffer allocation;
//! * **interpreter** replicas share the container bytes and pay only the
//!   runtime parse (that parse *is* the TFLM cost being modeled);
//! * **PJRT** sessions are built uncached, as everywhere else (their XLA
//!   state must stay single-owner).
//!
//! The factory is `Send + Sync`: the fleet tick loop holds it behind an
//! `Arc` and provisions from whatever thread drives the controller.
//! Provisioned sessions are labeled `prefix/N` with a monotonically
//! increasing N, so replica names stay unique across scale-up/down
//! cycles (a retired replica's index is never reused).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::faulty::FaultPlan;
use super::{Engine, ModelSource, Session, SessionCache};

/// A frozen recipe for building interchangeable session replicas.
pub struct ReplicaFactory {
    source: ModelSource,
    engine: Engine,
    paging: bool,
    preferred_batch: Option<usize>,
    label_prefix: String,
    cache: Arc<SessionCache>,
    provisioned: AtomicUsize,
    faults: HashMap<usize, FaultPlan>,
}

impl ReplicaFactory {
    /// A factory over `source` + `engine` with its own fresh warm cache
    /// and the engine name as the label prefix.
    pub fn new(source: impl Into<ModelSource>, engine: Engine) -> ReplicaFactory {
        ReplicaFactory {
            source: source.into(),
            engine,
            paging: false,
            preferred_batch: None,
            label_prefix: engine.name().to_string(),
            cache: Arc::new(SessionCache::new()),
            provisioned: AtomicUsize::new(0),
            faults: HashMap::new(),
        }
    }

    /// Share a deployment-wide warm cache instead of the factory's own
    /// (so initial pool builds and later scale-ups hit the same plans).
    pub fn cache(mut self, cache: &Arc<SessionCache>) -> ReplicaFactory {
        self.cache = Arc::clone(cache);
        self
    }

    /// Native-engine paged execution (see [`super::SessionBuilder::paging`]).
    pub fn paging(mut self, paging: bool) -> ReplicaFactory {
        self.paging = paging;
        self
    }

    /// Batch-size hint for provisioned sessions.
    pub fn preferred_batch(mut self, n: usize) -> ReplicaFactory {
        self.preferred_batch = Some(n.max(1));
        self
    }

    /// Label prefix for provisioned sessions (`prefix/N`).
    pub fn label_prefix(mut self, prefix: impl Into<String>) -> ReplicaFactory {
        self.label_prefix = prefix.into();
        self
    }

    /// Chaos hook: wrap the `index`-th provisioned replica (0-based, by
    /// provisioning order) in a seeded [`FaultPlan`]. Later indices stay
    /// healthy, so the same factory that seeds a faulty initial pool
    /// also supplies the clean warm replacements ejection provisions —
    /// all through one cache (the miss count stays pinned).
    pub fn fault(mut self, index: usize, plan: FaultPlan) -> ReplicaFactory {
        self.faults.insert(index, plan);
        self
    }

    /// Build one more replica session through the warm cache.
    pub fn provision(&self) -> Result<Session> {
        let n = self.provisioned.fetch_add(1, Ordering::Relaxed);
        let mut b = Session::builder(self.source.clone())
            .engine(self.engine)
            .paging(self.paging)
            .cache(&self.cache)
            .label(format!("{}/{n}", self.label_prefix));
        if let Some(pb) = self.preferred_batch {
            b = b.preferred_batch(pb);
        }
        let session = b.build()?;
        Ok(match self.faults.get(&n) {
            Some(plan) => plan.clone().wrap(session),
            None => session,
        })
    }

    /// Provision `n` replicas at once (the initial pool build).
    pub fn provision_n(&self, n: usize) -> Result<Vec<Session>> {
        (0..n).map(|_| self.provision()).collect()
    }

    /// Sessions provisioned so far (including failed builds' reserved
    /// label indices).
    pub fn provisioned(&self) -> usize {
        self.provisioned.load(Ordering::Relaxed)
    }

    /// The warm cache behind this factory (hit/miss introspection).
    pub fn warm_cache(&self) -> &Arc<SessionCache> {
        &self.cache
    }

    pub fn engine(&self) -> Engine {
        self.engine
    }
}

impl std::fmt::Debug for ReplicaFactory {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReplicaFactory")
            .field("engine", &self.engine)
            .field("paging", &self.paging)
            .field("label_prefix", &self.label_prefix)
            .field("provisioned", &self.provisioned())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::mfb::tests::tiny_mfb;

    #[test]
    fn provisions_working_uniquely_labeled_replicas() {
        let f = ReplicaFactory::new(tiny_mfb(), Engine::MicroFlow).label_prefix("pool-a");
        let mut a = f.provision().unwrap();
        let mut b = f.provision().unwrap();
        assert_eq!(a.label(), "pool-a/0");
        assert_eq!(b.label(), "pool-a/1");
        assert_eq!(f.provisioned(), 2);
        assert_eq!(a.run(&[3, 1]).unwrap(), vec![2, 0, 5]);
        assert_eq!(b.run(&[3, 1]).unwrap(), vec![2, 0, 5]);
    }

    #[test]
    fn native_scale_up_costs_no_recompile() {
        let f = ReplicaFactory::new(tiny_mfb(), Engine::MicroFlow);
        let _first = f.provision().unwrap();
        // the first build warms the cache: bytes miss + compile miss
        assert_eq!(f.warm_cache().misses(), 2);
        let _scaled: Vec<Session> = f.provision_n(3).unwrap();
        // every later replica is pure cache hits (bytes + plan each)
        assert_eq!(f.warm_cache().misses(), 2, "scale-up recompiled");
        assert_eq!(f.warm_cache().hits(), 6);
    }

    #[test]
    fn shares_a_deployment_cache() {
        let cache = Arc::new(SessionCache::new());
        let _initial =
            Session::builder(tiny_mfb()).engine(Engine::MicroFlow).cache(&cache).build().unwrap();
        let f = ReplicaFactory::new(tiny_mfb(), Engine::MicroFlow).cache(&cache);
        let _scaled = f.provision().unwrap();
        // the factory's build reuses the deployment's warm plan
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.hits(), 2);
    }

    #[test]
    fn provision_failure_is_an_error_not_a_panic() {
        let f = ReplicaFactory::new(vec![0u8, 1, 2, 3], Engine::MicroFlow);
        assert!(f.provision().is_err());
    }

    #[test]
    fn preferred_batch_and_paging_flow_through() {
        let f = ReplicaFactory::new(tiny_mfb(), Engine::MicroFlow).paging(true).preferred_batch(16);
        let s = f.provision().unwrap();
        assert_eq!(s.preferred_batch(), 16);
    }

    #[test]
    fn fault_hook_wraps_only_the_marked_index_and_keeps_cache_warm() {
        let f = ReplicaFactory::new(tiny_mfb(), Engine::MicroFlow)
            .label_prefix("chaos")
            .fault(1, FaultPlan::new(0).transient_every(1));
        let mut healthy = f.provision().unwrap();
        let mut faulty = f.provision().unwrap();
        let mut replacement = f.provision().unwrap();
        assert_eq!(faulty.label(), "chaos/1", "wrap must keep the replica label");
        assert_eq!(healthy.run(&[3, 1]).unwrap(), vec![2, 0, 5]);
        assert!(faulty.run(&[3, 1]).is_err(), "index 1 fails every call");
        assert_eq!(replacement.run(&[3, 1]).unwrap(), vec![2, 0, 5]);
        // the wrapper adds no compiles: one bytes miss + one plan miss total
        assert_eq!(f.warm_cache().misses(), 2);
    }
}
