//! # MicroFlow reproduction — three-layer Rust + JAX + Pallas stack
//!
//! This crate reproduces *"MicroFlow: An Efficient Rust-Based Inference
//! Engine for TinyML"* (Carnelos, Pasti, Bellotto, 2024) as a full system:
//!
//! * [`format`] — the MFB model container (TFLite-equivalent, DESIGN.md §4)
//!   plus dataset / golden-vector readers;
//! * [`tensor`] — int8 tensors and the two requantization arithmetics
//!   (MicroFlow float-scale vs TFLM gemmlowp fixed-point);
//! * [`kernels`] — the paper's quantized operator kernels (Sec. 5 + App. A);
//! * [`compiler`] — the MicroFlow Compiler: parse → internal representation
//!   → constant pre-processing (Eq. 4/7/10/13) → static execution plan →
//!   memory plan → paging plan (Sec. 3.3, 4);
//! * [`engine`] — the MicroFlow Runtime: static-allocation plan executor and
//!   the paged executor for 2 kB-RAM devices (Sec. 3.4, 4.3);
//! * [`interp`] — the TFLM-like interpreter baseline the paper compares
//!   against: runtime parsing, op resolver, tensor arena, dispatch;
//! * [`sim`] — the MCU substrate (Table 4 devices), cycle/memory/energy
//!   models used by the Fig. 9-11 / Table 6 benches;
//! * [`runtime`] — PJRT client loading the JAX-AOT'd HLO artifacts (the
//!   numerical oracle and host serving backend);
//! * [`coordinator`] — the serving layer: dynamic batcher, model router,
//!   worker pool, latency/throughput metrics;
//! * [`eval`] — datasets, accuracy metrics and the Table 5 runner.
//!
//! The Python side (`python/compile/`) runs **only at build time**
//! (`make artifacts`): it trains the three paper models, quantizes them,
//! exports `.mfb`/`.mds`/golden files and AOT-lowers the quantized Pallas
//! graphs to HLO text. Nothing in this crate imports Python.

pub mod bench_support;
pub mod cli;
pub mod compiler;
pub mod coordinator;
pub mod engine;
pub mod eval;
pub mod format;
pub mod interp;
pub mod kernels;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Repo-relative artifacts directory, overridable with `MICROFLOW_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("MICROFLOW_ARTIFACTS") {
        return p.into();
    }
    // examples/tests/benches run from the crate root
    let cand = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cand
}
