//! # MicroFlow reproduction — three-layer Rust + JAX + Pallas stack
//!
//! This crate reproduces *"MicroFlow: An Efficient Rust-Based Inference
//! Engine for TinyML"* (Carnelos, Pasti, Bellotto, 2024) as a full system.
//!
//! ## The front door: `microflow::api`
//!
//! All inference goes through one session-based surface — pick an engine,
//! build a session, run:
//!
//! ```no_run
//! use microflow::api::{Engine, Session};
//!
//! // the paper's system: compile once, static buffers, folded constants
//! let mut session = Session::builder("artifacts/sine.mfb")
//!     .engine(Engine::MicroFlow)   // or Engine::Interp / Engine::Pjrt
//!     .paging(false)               // Sec. 4.3 paged executor for 2 kB RAM
//!     .preferred_batch(8)          // what the dynamic batcher targets
//!     .build()?;
//!
//! let sig = session.signature().clone();       // shapes + quantization
//! let q = sig.input.quantize(&[1.0]);
//! let mut out = vec![0i8; sig.output.len()];
//! session.run_into(&q, &mut out)?;             // allocation-free hot path
//! # anyhow::Ok(())
//! ```
//!
//! All three executors implement [`api::InferenceSession`] and are
//! interchangeable behind [`api::Session`]: the coordinator's worker pool,
//! the CLI (`predict`/`verify`/`serve`), the examples and the benches all
//! run on this surface. **Migration from the pre-session constructors:**
//!
//! * `MicroFlowEngine::new(&model, CompileOptions { paging })` →
//!   `Session::builder(model).engine(Engine::MicroFlow).paging(paging).build()`;
//! * `Interpreter::new(&bytes, &OpResolver::with_all_kernels())` →
//!   `Session::builder(bytes).engine(Engine::Interp).build()`;
//! * `PjrtEngine::load(dir, name)` →
//!   `Session::builder(dir.join(format!("{name}.mfb"))).engine(Engine::Pjrt).build()`
//!   (requires the `pjrt` build feature);
//! * `Backend::execute(&inputs, n) -> Vec<i8>` (allocating, coordinator-
//!   private) → `Session::run_batch_into(&inputs, n, &mut out)`
//!   (allocation-free, public).
//!
//! The low-level types remain public for compilation introspection and the
//! simulator, but serving code should never construct them directly.
//!
//! ## Module map
//!
//! * [`api`] — **the public inference surface**: `TensorSpec`/`IoSignature`,
//!   `ModelSource`, `SessionBuilder`, the `InferenceSession` trait and the
//!   three engine sessions;
//! * [`format`] — the MFB model container (TFLite-equivalent, DESIGN.md §4)
//!   reader *and* writer, plus dataset / golden-vector readers;
//! * [`tensor`] — int8 tensors and the two requantization arithmetics
//!   (MicroFlow float-scale vs TFLM gemmlowp fixed-point);
//! * [`kernels`] — the paper's quantized operator kernels (Sec. 5 + App. A);
//! * [`compiler`] — the MicroFlow Compiler: parse → internal representation
//!   → constant pre-processing (Eq. 4/7/10/13) → static execution plan →
//!   memory plan → paging plan (Sec. 3.3, 4);
//! * [`engine`] — the MicroFlow Runtime: static-allocation plan executor and
//!   the paged executor for 2 kB-RAM devices (Sec. 3.4, 4.3);
//! * [`interp`] — the TFLM-like interpreter baseline the paper compares
//!   against: runtime parsing, op resolver, tensor arena, dispatch;
//! * [`sim`] — the MCU substrate (Table 4 devices), cycle/memory/energy
//!   models used by the Fig. 9-11 / Table 6 benches;
//! * [`runtime`] — PJRT client loading the JAX-AOT'd HLO artifacts (the
//!   numerical oracle and host serving backend; optional `pjrt` feature);
//! * [`coordinator`] — the serving layer: dynamic batcher (with
//!   per-replica adaptive tuning), heterogeneous replica-pool fleets with
//!   least-outstanding-requests dispatch, model router, worker pools over
//!   [`api::Session`] replicas, latency/throughput metrics, and the
//!   streaming affinity lane ([`coordinator::StreamHost`]);
//! * [`stream`] — pulsed stateful streaming: ring-buffer input state,
//!   the incremental per-frame executor and the replay oracle behind
//!   [`stream::StreamSession`] (planned + certified by
//!   [`compiler::pulse`]);
//! * [`observe`] — the zero-allocation observability plane: hot-path
//!   span rings, per-step kernel profiles ([`observe::StepProfiler`])
//!   and the Prometheus-text exposition tier behind `serve
//!   --metrics-addr`, the `STAT` wire op and `microflow top`;
//! * [`synth`] — seeded synthetic model generators backing the
//!   artifact-free conformance/stress suites and the fleet bench;
//! * [`eval`] — datasets, accuracy metrics and the Table 5 runner.
//!
//! The Python side (`python/compile/`) runs **only at build time**
//! (`make artifacts`): it trains the three paper models, quantizes them,
//! exports `.mfb`/`.mds`/golden files and AOT-lowers the quantized Pallas
//! graphs to HLO text. Nothing in this crate imports Python.
//!
//! ## Certification guarantees
//!
//! The paper's safety argument — a compiler-based engine plus Rust's
//! guarantees makes TinyML fit for critical environments — is *checked*,
//! not assumed. Two mechanisms:
//!
//! 1. **Static plan certification** ([`compiler::verify`]). Every
//!    [`compiler::CompiledModel`] built with default options carries a
//!    [`compiler::Certificate`] proving, by analysis and never by
//!    execution: the step chain is shape-sound end to end; packed panel
//!    images and depthwise pre-transposes match their geometry with zero
//!    tail lanes; page plans cover every FullyConnected row exactly once;
//!    the memory plan's peak/per-step/buffer/scratch claims equal an
//!    independent replay of the ping-pong schedule (whose construction
//!    proves input/output/scratch never alias while live); and worst-case
//!    interval arithmetic over the actual weights shows no i32 accumulator
//!    can overflow in any evaluation order (Eq. 4/7/10/13 epilogues
//!    included). `Session::builder(..).certify(false)` opts out.
//! 2. **A strict, never-panic decoder** ([`format::mfb`]). `MfbModel::parse`
//!    is total on arbitrary bytes — truncation, length/count overflow,
//!    index bounds, unknown enum codes and trailing bytes all surface as
//!    typed [`format::DecodeError`]s, a contract held by a seeded
//!    1000+-mutant harness (`tests/mfb_fuzz.rs`). The crate is
//!    `#![deny(unsafe_code)]` with audited exemptions only for
//!    `PjrtSession`'s `Send` impl and the SIMD kernel-backend modules
//!    (see *Kernel backends* below).
//!
//! Rejections carry stable codes — `V1xx` plan, `V2xx` memory, `V3xx`
//! arithmetic, `V4xx` pulse/streaming, `E4xx` decode — listed in
//! [`compiler::verify::ERROR_CODE_TABLE`] and printed by
//! `microflow audit --codes`. `microflow audit <model>` prints a
//! certificate report: peak-RAM bound, per-step live bytes and worst-case
//! accumulator headroom.
//!
//! ## Streaming sessions
//!
//! [`stream::StreamSession`] (re-exported from [`api`]) turns any model
//! with a streamable spatial prefix into a stateful frame-at-a-time
//! consumer: `push(frame)` returns `Some(verdict)` once a full window has
//! been seen and then at every pulse boundary, `None` while warming up or
//! mid-pulse. The pulse schedule is *compiled* ([`compiler::pulse`]) and
//! *certified* (`V401`–`V405`): ring/state regions are proven disjoint
//! and correctly sized, the cadence is proven consistent with the layer
//! strides, the state-shift/carry accounting is checked row by row, and
//! the pulsed path is proven to do **strictly less** kernel work than a
//! full-window re-run (`V405`, pinned by [`sim::cost`] MAC accounting).
//! The contract:
//!
//! * **State ownership** — all cross-frame state (the ring-buffer input
//!   window, per-layer row states, the carry activation) is owned by the
//!   session; the compiled plan itself stays immutable and shareable.
//! * **Bit-exactness vs replay** — every pulsed verdict equals, bit for
//!   bit, a full-window re-run of the same engine over the frames the
//!   ring holds at that push (`tests/stream_conformance.rs` asserts this
//!   at every frame, warmup included; the interpreter replay oracle
//!   carries its usual ±1-off-native tolerance *between* engines, while
//!   each engine is exact against its own replay).
//! * **Migration** — future verdicts are a pure function of ring
//!   contents: the coordinator's [`coordinator::StreamHost`] keeps a
//!   host-side ring per stream and re-primes a fresh session (boundary
//!   window + mid-pulse pending frames) when a replica is ejected, so a
//!   migrated stream's verdicts continue bit-exactly on the same cadence.
//!   Streams are pinned to one replica; the batcher never splits a
//!   stream across replicas.
//!
//! On the wire, `serve --stream` speaks the v3 `MFR3` frame-per-chunk
//! protocol (open/push/close with per-stream ids) alongside v1/v2.
//!
//! ## Kernel backends
//!
//! The hot-path i8×i8→i32 panel micro-kernels
//! ([`kernels::microkernel`]) are dispatched once per process through
//! [`kernels::microkernel::backend`]: the portable **scalar** backend is
//! always compiled (it is the reference oracle), and `std::arch` SIMD
//! backends — **avx2** on x86_64, **neon** on aarch64 — are selected at
//! startup when CPU feature detection reports them. Set
//! `MICROFLOW_KERNEL_BACKEND=scalar|avx2|neon` to force one; an unknown
//! or unavailable name panics at session construction rather than
//! silently falling back, so a CI leg forcing `avx2` can never quietly
//! test scalar.
//!
//! Every backend is held **bit-exact** to scalar: products of two `i8`
//! values fit `i16` with no saturation and the plan's accumulators are
//! exact `i32` sums, so any regrouping of the additions is identical —
//! the per-backend oracle sweeps in `tests/pack_equivalence.rs` assert
//! `assert_eq!` equality (not tolerance) across randomized shapes,
//! including the `kkc % stride` remainder tails. The SIMD modules are
//! the crate's only other `unsafe` exemptions: each carries a
//! module-level allow with `SAFETY` documentation, and the
//! `#[target_feature]` functions are reachable only through the runtime
//! feature check in `backend::resolve`.
//!
//! ## Fault tolerance guarantees
//!
//! The serving tier assumes replicas fail — wedged sessions, transient
//! engine errors, dead workers — and holds one invariant through all of
//! it: **every accepted request resolves exactly once**. The lifecycle
//! identity `completed + shed + cancelled + failed == submitted` is
//! asserted under seeded chaos (`tests/stress_coordinator.rs`), with
//! `retried` counted outside the identity (a retry is the same request
//! continuing, not a new one). The moving parts:
//!
//! * **Failure taxonomy** ([`coordinator::ReplicaError`]): every batch
//!   failure is typed with the replica label, the request id and a
//!   [`api::FailureKind`] — `Transient` (the request may be retried
//!   elsewhere) or `Fatal` (the worker marks its replica dead and exits;
//!   the autoscaler's `BelowMin` rule re-floors the pool). Unclassified
//!   engine errors are conservatively `Transient` — safe because retries
//!   are budget-bounded.
//! * **Deadline-budgeted retry** ([`coordinator::ServerConfig`]
//!   `max_retries`, default 1): a transiently-failed request is
//!   re-enqueued for a sibling replica unless its budget is spent, its
//!   deadline has passed or it was cancelled — never re-counted as
//!   `submitted`, never crossing its QoS class, recorded in the
//!   `retried` lane. Exhausted budgets resolve as `failed` with the
//!   typed error.
//! * **Replica health + auto-ejection** ([`coordinator::ReplicaHealth`],
//!   [`coordinator::HealthPolicy`]): per-replica consecutive-failure
//!   streaks and windowed failure rates; `Fleet::tick` quarantines a
//!   replica over threshold, provisions a warm replacement *first* (the
//!   pool never dips below its floor), then retires the sick worker via
//!   the graceful drain protocol. Ejected replicas stay in the registry
//!   as an incident log.
//! * **Per-pool circuit breakers** ([`coordinator::BreakerPolicy`],
//!   Closed → Open → HalfOpen): tick-counted like the autoscaler — no
//!   wall clock in policy. An open breaker **browns out**, not blacks
//!   out: Background and Bulk are shed at admission (resolved
//!   immediately with [`coordinator::SubmitError::BreakerOpen`]) while
//!   Interactive traffic always flows and doubles as the probe that
//!   re-closes the breaker. Sheds are excluded from the breaker's own
//!   error-rate window, so a brownout can never hold itself open.
//! * **Seeded fault injection** ([`api::FaultPlan`]): deterministic
//!   error/wedge/fatal/latency schedules wrap any session (compiled
//!   unconditionally, zero overhead when unused), so every path above is
//!   reproducible in CI from a fixed seed — same seed, same failures,
//!   same replies.
//!
//! ## Observability
//!
//! The [`observe`] plane makes the serving tier measurable without
//! perturbing it. Three tiers, strictly layered:
//!
//! * **Span recorder** ([`observe::SpanRing`]) — each pool keeps
//!   preallocated fixed-capacity rings of POD span events (request id,
//!   QoS class, phase admit → queue → batch → execute → reply, monotonic
//!   µs timestamps). Recording is allocation-free, lock-free and
//!   wait-free (one `fetch_add` + four atomic stores); a full ring
//!   **overwrites oldest-first** and every overwritten or torn event is
//!   counted in `SpanWindow::dropped` — loss is visible, never silent.
//!   Timestamps are taken in the recorder, outside policy code.
//! * **Per-step profiles** ([`observe::StepProfiler`]) — the
//!   [`observe::StepObserver`] hook threaded through the engine's plan
//!   executor accumulates per-layer nanoseconds + invocation counts into
//!   a fixed `[StepStat; MAX_STEPS]` table (TFLM-style op profiling,
//!   compile-time sized). Attachable to any session; surfaced by
//!   `microflow audit --profile` and the `profile_steps` bench. Pools
//!   started with `ServerConfig::profile` feed a shared atomic table.
//! * **Exposition** ([`observe::Exposition`]) — a Prometheus-text
//!   snapshot assembled **only** from windows the tick loop already
//!   drained, served by `microflow serve --metrics-addr`, the
//!   version-agnostic `STAT` wire op and the `microflow top` view. The
//!   exported request counters satisfy `completed + shed + cancelled +
//!   failed == submitted` per pool and class at quiescence.
//!
//! What is *not* on the hot path: draining, rendering and scraping all
//! happen in the tick loop or the metrics thread. The invariant the
//! suites hold: **observability is read-only** — no policy decision may
//! read a span ring, and exporters only consume drained windows.
//! `tests/alloc_free.rs` proves the predict path stays allocation-free
//! with both a span recorder and a `StepProfiler` attached.

#![deny(unsafe_code)]

pub mod api;
pub mod bench_support;
pub mod cli;
pub mod compiler;
pub mod coordinator;
pub mod engine;
pub mod eval;
pub mod format;
pub mod interp;
pub mod kernels;
pub mod observe;
pub mod runtime;
pub mod sim;
pub mod stream;
pub mod synth;
pub mod tensor;
pub mod util;

pub use api::{
    Engine, InferenceSession, IoSignature, ModelSource, Session, SessionBuilder, SessionCache,
    TensorSpec,
};

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Repo-relative artifacts directory, overridable with `MICROFLOW_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("MICROFLOW_ARTIFACTS") {
        return p.into();
    }
    // examples/tests/benches run from the crate root
    let cand = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cand
}
