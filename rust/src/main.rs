//! `microflow` — the leader binary: CLI over the whole reproduction stack.
//!
//! See [`microflow::cli::USAGE`] for subcommands. Everything here uses only
//! build-time artifacts (`make artifacts`); Python never runs. All
//! inference goes through [`microflow::api::Session`] — `predict`, `verify`
//! and `serve` select engines with the session builder.

use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use microflow::api::{Engine, FaultPlan, ReplicaFactory, Session, SessionCache};
use microflow::cli::{parse_autoscale, parse_chaos, parse_engine_mix, Args, USAGE};
use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::coordinator::{
    AutoscalePolicy, BreakerState, Client, Fleet, Ingress, PoolSpec, QosClass, QosProfile,
    Request, Router, ServerConfig, StreamFault, StreamHost, StreamHostConfig,
};
use microflow::format::golden::Golden;
use microflow::observe::{parse_exposition, Exposition, MetricsServer, Sample, StepProfiler};
use microflow::format::mds::MdsDataset;
use microflow::format::mfb::MfbModel;
use microflow::runtime::oracle::check_against_golden;
use microflow::sim;
use microflow::sim::mcu::by_name;
use microflow::util::{fmt_energy_wh, fmt_kb, fmt_time, Prng};

const MODELS: [&str; 3] = ["sine", "speech", "person"];

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "models" => cmd_models(),
        "predict" => cmd_predict(args),
        "verify" => cmd_verify(args),
        "deploy" => cmd_deploy(args),
        "audit" => cmd_audit(args),
        "serve" => cmd_serve(args),
        "top" => cmd_top(args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

fn artifacts() -> std::path::PathBuf {
    microflow::artifacts_dir()
}

fn model_arg(args: &Args) -> Result<&str> {
    args.positional
        .get(1)
        .map(|s| s.as_str())
        .context("missing <model> argument (sine | speech | person)")
}

/// `--engine NAME` (default microflow), parsed into the api enum.
fn engine_arg(args: &Args, key: &str) -> Result<Engine> {
    args.opt(key).unwrap_or("microflow").parse()
}

/// `microflow models` — the Table-3 inventory, regenerated from artifacts.
fn cmd_models() -> Result<()> {
    let art = artifacts();
    println!("{:8} | {:6} | {:>8} | {:>10} | {:>10} | {:>6} | ops", "model", "layers", "params*", "weights", "file", "test_n");
    println!("{}", "-".repeat(84));
    for name in MODELS {
        let path = art.join(format!("{name}.mfb"));
        if !path.exists() {
            println!("{name:8} | (missing — run `make artifacts`)");
            continue;
        }
        let m = MfbModel::load(&path)?;
        let c = CompiledModel::compile(&m, CompileOptions::default())?;
        let ds = MdsDataset::load(art.join(format!("{name}_test.mds")))?;
        let mut kinds: Vec<&str> = c.steps.iter().map(|s| s.kind.name()).collect();
        kinds.dedup();
        println!(
            "{name:8} | {:6} | {:>8} | {:>10} | {:>10} | {:>6} | {}",
            c.steps.len(),
            c.total_macs(),
            fmt_kb(m.weights_bytes()),
            fmt_kb(m.file_bytes),
            ds.n,
            kinds.join(",")
        );
    }
    println!("\n* params column shows MACs per inference (cost-model driver)");
    Ok(())
}

/// `microflow predict <model> [--index N] [--engine E] [--paging]`.
fn cmd_predict(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    let art = artifacts();
    let engine = engine_arg(args, "engine")?;
    let mut session = Session::builder(art.join(format!("{name}.mfb")))
        .engine(engine)
        .paging(args.flag("paging"))
        .build()?;
    let ds = MdsDataset::load(art.join(format!("{name}_test.mds")))?;
    let idx = args.opt_usize("index", 0).min(ds.n - 1);
    let t0 = Instant::now();
    let out = session.run_f32(ds.sample(idx))?;
    let dt = t0.elapsed();
    println!("model={name} engine={engine} sample={idx} latency={}", fmt_time(dt.as_secs_f64()));
    println!("output: {out:?}");
    match &ds.labels {
        microflow::format::mds::Labels::Classes(c) => println!("true class: {}", c[idx]),
        microflow::format::mds::Labels::Regression { .. } => {
            println!("true value: {:?}", ds.target(idx))
        }
    }
    Ok(())
}

/// `microflow verify <model>` — cross-check every engine against the JAX
/// golden vectors, all constructed through the session builder.
fn cmd_verify(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    let art = artifacts();
    let golden = Golden::load(art.join(format!("{name}_golden.bin")))?;
    let mfb_path = art.join(format!("{name}.mfb"));

    let mut native = Session::builder(&mfb_path).engine(Engine::MicroFlow).build()?;
    let a = check_against_golden(&golden, |x| native.run(x))?;
    println!("microflow engine : exact {}/{} (max |Δ| = {})", a.exact, a.n_outputs, a.max_abs_diff);
    anyhow::ensure!(a.is_bit_exact(), "microflow engine is not bit-exact vs the JAX oracle");

    let mut interp = Session::builder(&mfb_path).engine(Engine::Interp).build()?;
    let b = check_against_golden(&golden, |x| interp.run(x))?;
    println!("tflm interpreter : exact {}/{} (max |Δ| = {})", b.exact, b.n_outputs, b.max_abs_diff);
    if !b.is_within_one() {
        // fixed-point vs float-scale requantization differences compound
        // across deep models (paper Sec. 6.2.1 observes the per-operator
        // ±1); the decision-level gate is argmax agreement
        let mut agree = 0usize;
        for i in 0..golden.n {
            let out = interp.run(golden.input(i))?;
            if microflow::eval::accuracy::argmax(&out)
                == microflow::eval::accuracy::argmax(golden.output(i))
            {
                agree += 1;
            }
        }
        println!("tflm interpreter : argmax agreement {agree}/{}", golden.n);
        anyhow::ensure!(agree == golden.n, "interpreter argmax disagrees with the oracle");
    }

    // PJRT is an optional build feature: on a default build the stub can
    // never load, so the check is skipped with a notice. On a pjrt build
    // a construction failure is a real verification failure (missing or
    // corrupt HLO artifacts must not silently pass the oracle gate).
    if cfg!(feature = "pjrt") {
        let mut pjrt = Session::builder(&mfb_path).engine(Engine::Pjrt).build()?;
        let c = check_against_golden(&golden, |x| pjrt.run(x))?;
        println!("pjrt (AOT HLO)   : exact {}/{} (max |Δ| = {})", c.exact, c.n_outputs, c.max_abs_diff);
        anyhow::ensure!(c.is_bit_exact(), "PJRT path is not bit-exact vs the JAX oracle");
    } else {
        println!("pjrt (AOT HLO)   : skipped — built without the `pjrt` feature");
    }

    println!("verify {name}: OK");
    Ok(())
}

/// `microflow deploy <model> <mcu> [--paging] [--engine microflow|tflm]`.
fn cmd_deploy(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    let mcu_name = args.positional.get(2).context("missing <mcu> argument")?;
    let mcu = by_name(mcu_name).with_context(|| format!("unknown MCU {mcu_name:?}"))?;
    let engine = args.opt("engine").unwrap_or("microflow");
    let art = artifacts();
    let m = MfbModel::load(art.join(format!("{name}.mfb")))?;
    let opts = CompileOptions { paging: args.flag("paging"), ..Default::default() };
    let compiled = CompiledModel::compile(&m, opts)?;

    let (eng, fp) = match engine {
        "microflow" => (sim::Engine::MicroFlow, sim::memory_model::microflow_footprint(&compiled, mcu)),
        "tflm" => {
            let arena = microflow::interp::arena::ArenaPlan::plan(&m)?;
            (sim::Engine::Tflm, sim::memory_model::tflm_footprint(&m, &arena, mcu))
        }
        other => bail!("unknown engine {other:?}"),
    };
    println!("deploy {name} with {engine} on {} ({})", mcu.name, mcu.board);
    println!("  flash: {:>10} / {:>10}", fmt_kb(fp.flash), fmt_kb(mcu.flash_bytes));
    println!("  ram:   {:>10} / {:>10}", fmt_kb(fp.ram), fmt_kb(mcu.ram_bytes));
    match sim::memory_model::fits(mcu, eng, fp) {
        Ok(()) => {
            let secs = sim::inference_seconds(&compiled, mcu, eng);
            let wh = sim::energy::inference_energy_wh(&compiled, mcu, eng);
            println!("  fits: yes");
            println!("  modeled inference time: {}", fmt_time(secs));
            println!("  modeled energy/inference: {}", fmt_energy_wh(wh));
            if let Some(p) = compiled.page_plan {
                println!("  paging: {} pages, {} per page (unpaged {})",
                    p.pages, fmt_kb(p.page_bytes), fmt_kb(p.unpaged_bytes));
            }
            // Sec. 4.4: stack-overflow protection status on this target
            let layout = sim::stack_guard::microflow_layout(mcu);
            println!(
                "  stack layout: {:?} (overflow on this target is {})",
                layout,
                if sim::stack_guard::flip_link_available(mcu.arch) {
                    "a detectable hardware exception (flip-link)"
                } else {
                    "UNPROTECTED (flip-link is Cortex-M only)"
                }
            );
        }
        Err(e) => println!("  fits: NO — {e}"),
    }
    Ok(())
}

/// `microflow audit <model|path> [--paging]` — statically certify a
/// compiled plan and print its certificate report. `--synth-zoo [--seed N]`
/// certifies every synthetic-zoo model instead (both paging modes; the CI
/// gate), and `--codes` prints the stable error-code table.
fn cmd_audit(args: &Args) -> Result<()> {
    if args.flag("codes") {
        print!("{}", microflow::compiler::ERROR_CODE_TABLE);
        return Ok(());
    }
    if args.flag("synth-zoo") {
        let seed = args.opt_usize("seed", 20_260_731) as u64;
        let mut failures = 0usize;
        for (name, m) in microflow::synth::zoo(seed) {
            // through the serializer: certify the exact bytes an engine
            // would be handed, not the in-memory construction
            let bytes = microflow::format::builder::serialize(&m)?;
            let parsed = MfbModel::parse(&bytes)?;
            for paging in [false, true] {
                match CompiledModel::compile(&parsed, CompileOptions { paging, certify: true }) {
                    Ok(c) => {
                        let cert = c.certificate.as_ref().expect("certify was on");
                        println!(
                            "{name:12} paging={paging:5}  peak RAM {:>6} B  headroom {:>2} bits",
                            cert.peak_ram,
                            cert.min_headroom_bits()
                        );
                    }
                    Err(e) => {
                        failures += 1;
                        println!("{name:12} paging={paging:5}  REJECTED — {e:#}");
                    }
                }
            }
        }
        anyhow::ensure!(failures == 0, "{failures} synth-zoo plan(s) failed certification");
        println!("synth zoo (seed {seed}): every plan certified");
        return Ok(());
    }

    let name = args.positional.get(1).map(|s| s.as_str()).context(
        "missing <model> argument (an artifact name, a path to an .mfb, \
         or --synth-zoo / --codes)",
    )?;
    let path = if std::path::Path::new(name).is_file() {
        std::path::PathBuf::from(name)
    } else {
        artifacts().join(format!("{name}.mfb"))
    };
    let m = MfbModel::load(&path)?;
    let opts = CompileOptions { paging: args.flag("paging"), certify: true };
    let compiled = CompiledModel::compile(&m, opts)
        .with_context(|| format!("{} failed certification", path.display()))?;
    let cert = compiled.certificate.as_ref().expect("certify was on");
    println!("{cert}");
    println!("audit {}: certified", path.display());
    if args.flag("profile") {
        audit_profile(&path, args)?;
    }
    Ok(())
}

/// `audit --profile [--runs N]` tail: run N profiled zero-input
/// inferences through the native engine with a [`StepProfiler`] attached
/// and print the per-step kernel profile. The profiler writes into a
/// fixed table, so the observed runs stay on the allocation-free path.
fn audit_profile(path: &std::path::Path, args: &Args) -> Result<()> {
    let runs = args.opt_usize("runs", 100).max(1);
    let mut session = Session::builder(path)
        .engine(Engine::MicroFlow)
        .paging(args.flag("paging"))
        .build()?;
    let input = vec![0i8; session.input_len()];
    let mut out = vec![0i8; session.output_len()];
    let mut profiler = StepProfiler::new();
    // one unprofiled warmup keeps cold-start noise out of step 0's column
    session.run_into(&input, &mut out)?;
    let t0 = Instant::now();
    for _ in 0..runs {
        session.run_into_observed(&input, &mut out, &mut profiler)?;
    }
    let wall = t0.elapsed();
    let kinds = session.step_kinds();
    let rows = profiler.rows(&kinds);
    println!(
        "\nper-step kernel profile ({runs} inference(s), {:.2} ms wall):",
        wall.as_secs_f64() * 1e3
    );
    println!("{:>4} | {:16} | {:>8} | {:>12} | {:>10}", "step", "kind", "calls", "total ns", "ns/call");
    println!("{}", "-".repeat(62));
    let mut total_ns = 0u64;
    for r in &rows {
        total_ns += r.total_ns;
        println!(
            "{:>4} | {:16} | {:>8} | {:>12} | {:>10}",
            r.step, r.kind, r.invocations, r.total_ns, r.ns_per_call()
        );
    }
    println!("{}", "-".repeat(62));
    println!("{:>4} | {:16} | {:>8} | {:>12} |", "", "total", runs, total_ns);
    if profiler.overflow() > 0 {
        println!("note: {} step(s) beyond the fixed profile table were not counted", profiler.overflow());
    }
    Ok(())
}

/// `microflow serve <model> [--requests N] [--rate RPS] [--backend B]
/// [--replicas R] [--engine-mix MIX] [--batch B] [--no-adaptive]
/// [--paging] [--default-class C] [--shed-after-ms MS]
/// [--autoscale MIN:MAX] [--slo-p95-ms MS] [--tick-ms MS] [--retries N]
/// [--no-breaker] [--chaos SEED[:P]]` — synthetic serving load over a
/// replica fleet (typed requests with QoS classes and optional
/// deadlines), prints per-pool, per-class metrics. With `--autoscale`,
/// every pool is elastic: the SLO-driven controller ticks on a fixed
/// cadence during the run, printing each scale decision and the windowed
/// rates it acted on. With `--chaos`, one replica per pool runs under the
/// seeded fault injector so the tick loop also exercises retry, health
/// ejection and the circuit breaker.
fn cmd_serve(args: &Args) -> Result<()> {
    if args.flag("stream") {
        return cmd_serve_stream(args);
    }
    let name = model_arg(args)?;
    let art = artifacts();
    let requests = args.opt_usize("requests", 500);
    let rate = args.opt_f64("rate", 200.0);
    let max_batch = args.opt_usize("batch", 8);
    let autoscale: Option<(usize, usize)> =
        args.opt("autoscale").map(parse_autoscale).transpose()?;
    let slo_p95: Option<Duration> = args
        .opt("slo-p95-ms")
        .map(|v| v.parse::<u64>().context("--slo-p95-ms"))
        .transpose()?
        .map(Duration::from_millis);
    let tick_every = Duration::from_millis(args.opt_usize("tick-ms", 100) as u64);
    // `mix` draws a deterministic blend of classes per request; a named
    // class pins the whole load to it
    let default_class: Option<QosClass> = match args.opt("default-class").unwrap_or("mix") {
        "mix" => None,
        c => Some(c.parse()?),
    };
    let shed_after: Option<Duration> =
        args.opt("shed-after-ms").map(|v| v.parse::<u64>().context("--shed-after-ms")).transpose()?
            .map(Duration::from_millis);
    let chaos: Option<(u64, u64)> = args.opt("chaos").map(parse_chaos).transpose()?;
    let metrics_addr: Option<&str> = args.opt("metrics-addr");

    // pool layout: --engine-mix pools, or a single --backend x --replicas
    let mix: Vec<(Engine, usize)> = match args.opt("engine-mix") {
        Some(s) => parse_engine_mix(s)?,
        None => vec![(engine_arg(args, "backend")?, args.opt_usize("replicas", 2))],
    };

    let mfb_path = art.join(format!("{name}.mfb"));
    let cache = std::sync::Arc::new(SessionCache::new());
    let mut cfg = ServerConfig { adaptive: !args.flag("no-adaptive"), ..ServerConfig::default() };
    cfg.batcher.max_batch = max_batch;
    cfg.max_retries = args.opt_usize("retries", 1) as u32;
    cfg.profile = args.flag("profile");
    // single-pool layouts keep the profile open (Any) so every class is
    // served; multi-pool fleets get the engine-derived QoS profiles the
    // class-aware dispatch routes on
    let single_pool = mix.len() == 1;
    let pools = mix
        .iter()
        .map(|&(engine, replicas)| {
            // ONE replica recipe per pool: the initial sessions and any
            // autoscale growth provision through the same factory (and
            // the same warm cache — native growth costs no recompile),
            // so scaled replicas can never drift from the originals
            let mut factory = ReplicaFactory::new(&mfb_path, engine)
                .paging(args.flag("paging"))
                .preferred_batch(max_batch)
                .cache(&cache);
            if let Some((seed, period)) = chaos {
                // deterministic chaos: the pool's first replica fails every
                // `period`-th call, phase-shifted by the seed
                factory = factory.fault(0, FaultPlan::new(seed).transient_every(period));
            }
            let factory = std::sync::Arc::new(factory);
            let sessions: Vec<Session> = factory.provision_n(replicas)?;
            let profile =
                if single_pool { QosProfile::Any } else { QosProfile::for_engine(engine) };
            let mut spec = PoolSpec::new(format!("{engine}x{replicas}"), sessions)
                .config(cfg)
                .profile(profile);
            if args.flag("no-breaker") {
                spec = spec.no_breaker();
            }
            if let Some((min, max)) = autoscale {
                let mut policy = AutoscalePolicy::new(min, max);
                if let Some(t) = slo_p95 {
                    policy = policy.slo_p95(t);
                }
                spec = spec.autoscale(policy, factory);
            }
            Ok(spec)
        })
        .collect::<Result<Vec<_>>>()?;
    let fleet = Fleet::start(pools)?;
    // exposition tier: assembled only from tick-drained windows, served
    // over plain HTTP for scrapers (the STAT wire op reads the same sink)
    let expo: Option<std::sync::Arc<Exposition>> =
        metrics_addr.map(|_| std::sync::Arc::new(Exposition::new()));
    let metrics_srv = match (metrics_addr, &expo) {
        (Some(addr), Some(e)) => {
            let srv = MetricsServer::start(addr, std::sync::Arc::clone(e))?;
            println!(
                "metrics: Prometheus exposition at http://{}/metrics \
                 (tick-drained; `microflow top {}` renders it)",
                srv.local_addr(),
                srv.local_addr()
            );
            Some(srv)
        }
        _ => None,
    };
    if cfg.profile {
        println!("profile: per-step kernel profiler attached to every worker");
    }
    if let Some((seed, period)) = chaos {
        println!(
            "chaos: replica 0 of every pool fails every {period}th call \
             (seed {seed}, transient — retry budget {})",
            cfg.max_retries
        );
    }
    if let Some((min, max)) = autoscale {
        println!(
            "autoscale: each pool elastic in [{min}..{max}] replicas, tick every {}ms{}",
            tick_every.as_millis(),
            slo_p95
                .map(|t| format!(", interactive p95 SLO {}ms", t.as_millis()))
                .unwrap_or_default(),
        );
    }
    println!(
        "warm session cache: {} hits / {} misses across {} replicas",
        cache.hits(),
        cache.misses(),
        fleet.replicas()
    );
    // which micro-kernel backend the native replicas run on — needed to
    // interpret any throughput numbers this run prints
    println!(
        "kernel backend: {} (available: [{}]; force with MICROFLOW_KERNEL_BACKEND)",
        microflow::kernels::microkernel::backend::active().name(),
        microflow::kernels::microkernel::backend::available().join(", ")
    );

    // synthetic Poisson open-loop load from the test set
    let ds = MdsDataset::load(art.join(format!("{name}_test.mds")))?;
    let qp = fleet.input_qparams();
    let mut rng = Prng::new(42);
    println!(
        "serving {name} via [{}]: {requests} requests @ ~{rate} rps (class {}, shed after {})",
        fleet.pool_names().join(", "),
        default_class.map(|c| c.name()).unwrap_or("mix"),
        shed_after.map(|d| format!("{}ms", d.as_millis())).unwrap_or_else(|| "never".into()),
    );
    // tick helper: run one control step, print every non-hold decision
    // (scale actions AND health ejections) with the window rates it acted
    // on, plus any pool whose breaker is away from Closed — windowed, not
    // lifetime, so a long-running session's status stays meaningful
    let run_tick = |label: &str| {
        let reports = fleet.tick();
        if let Some(e) = &expo {
            e.absorb_tick(&reports);
        }
        for r in &reports {
            if r.acted() || r.breaker.is_some_and(|b| b != BreakerState::Closed) {
                println!("tick {label}: {r}");
            }
        }
    };
    let ticking = autoscale.is_some() || chaos.is_some() || expo.is_some();
    let mut pending = Vec::new();
    let mut shed = 0usize;
    let t0 = Instant::now();
    let mut last_tick = Instant::now();
    for i in 0..requests {
        let sample = ds.sample(i % ds.n);
        let q = qp.quantize_slice(sample);
        // deterministic blend: half interactive, ~40% bulk, ~10% background
        let class = default_class.unwrap_or_else(|| match rng.below(10) {
            0..=4 => QosClass::Interactive,
            5..=8 => QosClass::Bulk,
            _ => QosClass::Background,
        });
        let mut req = Request::new(q).with_class(class);
        if let Some(d) = shed_after {
            req = req.with_deadline_in(d);
        }
        match fleet.submit(req) {
            Ok(t) => pending.push(t),
            // an open breaker resolves background work at the door —
            // already counted in the pool's shed lane, no ticket issued
            Err(e) if format!("{e:#}").contains("shed at admission") => shed += 1,
            Err(e) => return Err(e),
        }
        if ticking && last_tick.elapsed() >= tick_every {
            run_tick("load");
            last_tick = Instant::now();
        }
        std::thread::sleep(Duration::from_secs_f64(rng.exp(rate)));
    }
    let mut served = 0usize;
    let mut failed = 0usize;
    for ticket in pending {
        match ticket.wait() {
            Ok(_) => served += 1,
            // with --shed-after-ms, shed requests are an expected outcome
            Err(e) if format!("{e:#}").contains("shed") => shed += 1,
            // under --chaos, exhausted retry budgets are expected too:
            // the request resolved with a typed per-replica error
            Err(e) if format!("{e:#}").contains("failed on replica") => failed += 1,
            Err(e) => return Err(e),
        }
    }
    let wall = t0.elapsed();
    if ticking {
        // idle ticks after the drain: show the pool shrinking back toward
        // its floor (and any open breaker re-closing) before the snapshot;
        // with metrics on, they also drain the final spans and windows
        // into the exposition
        for _ in 0..8 {
            std::thread::sleep(tick_every);
            run_tick("idle");
        }
    }
    println!(
        "done in {:.2}s ({served} served, {shed} shed, {failed} failed)\n{}",
        wall.as_secs_f64(),
        fleet.snapshot()
    );
    if let Some(e) = &expo {
        // the drained pools are quiescent, so the exported lanes must hold
        // the lifecycle identity class-by-class
        println!(
            "exposition lane identity (completed + shed + cancelled + failed == submitted): {}",
            if e.identity_holds() { "ok" } else { "VIOLATED" }
        );
    }
    if let Some(srv) = metrics_srv {
        srv.shutdown();
    }
    fleet.shutdown();
    if let Some(e) = &expo {
        anyhow::ensure!(e.identity_holds(), "exported lane identity violated");
    }
    Ok(())
}

/// `microflow serve <model> --stream [--streams N] [--frames N]
/// [--stream-replicas R] [--seed N] [--chaos SEED[:P]]` — pulsed
/// streaming over the v3 `MFR3` wire protocol: plan + certify the pulse
/// pass, start a [`StreamHost`] behind a TCP ingress, drive N concurrent
/// client streams frame-per-chunk, and print the per-stream lifecycle
/// counters (the exactly-once identity is enforced). `<model>` may be
/// `synth` for a seeded synthetic streaming model — no artifacts needed.
/// With `--chaos`, stream replica 0 fails every P-th push, so the run
/// also exercises quarantine, migration-by-ring-replay and cadence
/// continuation.
fn cmd_serve_stream(args: &Args) -> Result<()> {
    let name = model_arg(args)?;
    let streams = args.opt_usize("streams", 4).max(1);
    let frames = args.opt_usize("frames", 64);
    let replicas = args.opt_usize("stream-replicas", 2);
    let seed = args.opt_usize("seed", 20_260_731) as u64;
    let chaos: Option<(u64, u64)> = args.opt("chaos").map(parse_chaos).transpose()?;

    let model = if name == "synth" {
        microflow::synth::stream_conv_chain(&mut Prng::new(seed), 2)
    } else {
        MfbModel::load(artifacts().join(format!("{name}.mfb")))?
    };
    let compiled = std::sync::Arc::new(CompiledModel::compile(&model, CompileOptions::default())?);
    let plan = microflow::compiler::PulsePlan::plan(&compiled)?;
    println!(
        "stream plan: window {} rows x {} B/frame, pulse every {} frame(s), \
         prefix {} of {} steps, state {} B, per-pulse work {:.1}% of a \
         full-window re-run (certified V401-V405)",
        plan.window_rows,
        plan.frame_len,
        plan.pulse_frames,
        plan.prefix.len(),
        compiled.steps.len(),
        plan.total_state_bytes(),
        plan.savings_ratio(&compiled) * 100.0,
    );
    let host = std::sync::Arc::new(StreamHost::start(
        compiled,
        StreamHostConfig { replicas, eject_after: 3 },
    )?);
    if let Some((_, period)) = chaos {
        host.inject_fault(StreamFault { worker: 0, every: period });
        println!(
            "chaos: stream replica 0 fails every {period}th push \
             (quarantine ejects it; its streams migrate via ring replay)"
        );
    }
    let mut router = Router::new();
    router.add_stream_host(name, host.clone());
    // optional exposition tier: per-stream counters surface as
    // microflow_stream_* metrics over HTTP and the STAT wire op
    let expo: Option<std::sync::Arc<Exposition>> =
        args.opt("metrics-addr").map(|_| std::sync::Arc::new(Exposition::new()));
    let metrics_srv = match (args.opt("metrics-addr"), &expo) {
        (Some(addr), Some(e)) => {
            router.set_exposition(std::sync::Arc::clone(e));
            let srv = MetricsServer::start(addr, std::sync::Arc::clone(e))?;
            println!("metrics: Prometheus exposition at http://{}/metrics", srv.local_addr());
            Some(srv)
        }
        _ => None,
    };
    let ingress = Ingress::start("127.0.0.1:0", std::sync::Arc::new(router))?;
    println!(
        "serving {streams} stream(s) x {frames} frames of {name} over MFR3 at {} \
         ({replicas} pinned replica(s))",
        ingress.addr
    );

    let mut clients: Vec<(Client, u64)> = (0..streams)
        .map(|_| {
            let mut c = Client::connect(ingress.addr)?;
            let id = c.open_stream(name)?;
            Ok((c, id))
        })
        .collect::<Result<Vec<_>>>()?;
    let mut rng = Prng::new(seed ^ 0x5eed);
    let frame_len = host.frame_len();
    let mut verdicts = 0usize;
    let mut soft_errors = 0usize;
    for fi in 0..frames {
        for (c, id) in clients.iter_mut() {
            let frame = rng.i8_vec(frame_len);
            match c.push_frame(*id, &frame) {
                Ok(Some(_)) => verdicts += 1,
                Ok(None) => {}
                // shed/failed pushes keep the stream alive — the frame is
                // already in the host ring; counted and carried on
                Err(_) => soft_errors += 1,
            }
        }
        if chaos.is_some() && fi % 16 == 15 {
            let r = host.tick();
            if !r.ejected.is_empty() {
                println!(
                    "tick: ejected [{}], migrated {} stream(s)",
                    r.ejected.join(", "),
                    r.migrated
                );
            }
        }
        if let Some(e) = &expo {
            if fi % 16 == 15 {
                e.absorb_streams(name, &host.snapshot());
            }
        }
    }
    if let Some(e) = &expo {
        // final absorb while the streams are still open — close removes
        // them from the host aggregate
        e.absorb_streams(name, &host.snapshot());
    }
    let mut all_ok = true;
    for (c, id) in clients.iter_mut() {
        let counters = c.close_stream(*id)?;
        all_ok &= counters.identity_holds();
        println!(
            "stream {id}: submitted {} completed {} shed {} cancelled {} failed {} \
             verdicts {} (identity {})",
            counters.submitted,
            counters.completed,
            counters.shed,
            counters.cancelled,
            counters.failed,
            counters.verdicts,
            if counters.identity_holds() { "ok" } else { "VIOLATED" },
        );
    }
    println!("done: {verdicts} verdict(s), {soft_errors} soft push error(s)");
    if let Some(srv) = metrics_srv {
        srv.shutdown();
    }
    ingress.shutdown();
    anyhow::ensure!(all_ok, "per-stream lifecycle identity violated");
    Ok(())
}

/// `microflow top <addr> [--wire]` — scrape one exposition snapshot from
/// a serving deployment (HTTP `--metrics-addr` endpoint, or the ingress
/// `STAT` wire op with `--wire`) and render it as per-pool request-lane,
/// span and kernel-profile tables.
fn cmd_top(args: &Args) -> Result<()> {
    let addr = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .context("missing <addr> argument (the deployment's --metrics-addr, or its ingress address with --wire)")?;
    let body = if args.flag("wire") {
        Client::connect(addr)?.stats()?
    } else {
        http_get(addr)?
    };
    let samples = parse_exposition(&body);
    if samples.is_empty() {
        // placeholder comment (no exposition attached) or an empty sink
        print!("{body}");
        return Ok(());
    }
    render_top(&samples);
    Ok(())
}

/// One blocking HTTP/1.0 GET against the metrics endpoint; returns the
/// response body.
fn http_get(addr: &str) -> Result<String> {
    use std::io::{Read, Write};
    let mut conn = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connect to metrics endpoint {addr}"))?;
    conn.write_all(format!("GET /metrics HTTP/1.0\r\nHost: {addr}\r\n\r\n").as_bytes())?;
    let mut resp = String::new();
    conn.read_to_string(&mut resp)?;
    match resp.split_once("\r\n\r\n") {
        Some((head, body)) if head.starts_with("HTTP/1.0 200") => Ok(body.to_string()),
        Some((head, _)) => bail!("metrics endpoint answered: {}", head.lines().next().unwrap_or("")),
        None => bail!("malformed HTTP response from {addr}"),
    }
}

/// Render parsed exposition samples as per-pool tables (the `top` view).
fn render_top(samples: &[Sample]) {
    let find = |name: &str, labels: &[(&str, &str)]| -> Option<f64> {
        samples
            .iter()
            .find(|s| s.name == name && labels.iter().all(|&(k, v)| s.label(k) == Some(v)))
            .map(|s| s.value)
    };
    let get = |name: &str, labels: &[(&str, &str)]| find(name, labels).unwrap_or(0.0);

    let mut pools: Vec<&str> = samples
        .iter()
        .filter(|s| s.name == "microflow_requests_total")
        .filter_map(|s| s.label("pool"))
        .collect();
    pools.sort_unstable();
    pools.dedup();

    for &pool in &pools {
        let breaker = match find("microflow_breaker_state", &[("pool", pool)]).map(|v| v as u8) {
            Some(0) => ", breaker closed",
            Some(1) => ", breaker OPEN",
            Some(2) => ", breaker half-open",
            _ => "",
        };
        println!(
            "pool {pool}: {} live replica(s), {} ejected, autoscale {} up / {} down{breaker}",
            get("microflow_replicas", &[("pool", pool)]),
            get("microflow_replicas_ejected_total", &[("pool", pool)]),
            get("microflow_autoscale_decisions_total", &[("pool", pool), ("action", "up")]),
            get("microflow_autoscale_decisions_total", &[("pool", pool), ("action", "down")]),
        );
        println!(
            "  {:12} | {:>9} | {:>9} | {:>6} | {:>9} | {:>6} | {:>7} | {:>9}",
            "class", "submitted", "completed", "shed", "cancelled", "failed", "retried", "p95 us"
        );
        for class in ["interactive", "bulk", "background"] {
            let lane = |outcome: &str| {
                get(
                    "microflow_requests_total",
                    &[("pool", pool), ("class", class), ("outcome", outcome)],
                )
            };
            println!(
                "  {:12} | {:>9} | {:>9} | {:>6} | {:>9} | {:>6} | {:>7} | {:>9.1}",
                class,
                lane("submitted"),
                lane("completed"),
                lane("shed"),
                lane("cancelled"),
                lane("failed"),
                lane("retried"),
                get("microflow_window_p95_us", &[("pool", pool), ("class", class)]),
            );
        }
        let span_cells: Vec<String> = ["admit", "queue", "batch", "execute", "reply"]
            .iter()
            .map(|&phase| {
                let total: f64 = samples
                    .iter()
                    .filter(|s| {
                        s.name == "microflow_span_events_total"
                            && s.label("pool") == Some(pool)
                            && s.label("phase") == Some(phase)
                    })
                    .map(|s| s.value)
                    .sum();
                format!("{phase} {total}")
            })
            .collect();
        println!(
            "  spans: {} (dropped {})",
            span_cells.join(" | "),
            get("microflow_spans_dropped_total", &[("pool", pool)]),
        );
        let mut steps: Vec<(usize, &str, f64, f64)> = samples
            .iter()
            .filter(|s| {
                s.name == "microflow_step_invocations_total" && s.label("pool") == Some(pool)
            })
            .filter_map(|s| {
                let step: usize = s.label("step")?.parse().ok()?;
                let kind = s.label("kind")?;
                let ns = get(
                    "microflow_step_ns_total",
                    &[("pool", pool), ("step", s.label("step")?), ("kind", kind)],
                );
                Some((step, kind, s.value, ns))
            })
            .collect();
        steps.sort_unstable_by_key(|&(step, ..)| step);
        if !steps.is_empty() {
            println!(
                "  {:>4} | {:16} | {:>9} | {:>12} | {:>10}",
                "step", "kind", "calls", "total ns", "ns/call"
            );
            for (step, kind, calls, ns) in steps {
                let per = if calls > 0.0 { ns / calls } else { 0.0 };
                println!("  {step:>4} | {kind:16} | {calls:>9} | {ns:>12} | {per:>10.1}");
            }
        }
    }

    let mut models: Vec<&str> = samples
        .iter()
        .filter(|s| s.name == "microflow_stream_pushes_total")
        .filter_map(|s| s.label("model"))
        .collect();
    models.sort_unstable();
    models.dedup();
    for model in models {
        let lane = |outcome: &str| {
            get("microflow_stream_pushes_total", &[("model", model), ("outcome", outcome)])
        };
        println!(
            "stream {model}: pushes {}/{} done ({} shed, {} cancelled, {} failed), {} verdict(s)",
            lane("completed"),
            lane("submitted"),
            lane("shed"),
            lane("cancelled"),
            lane("failed"),
            get("microflow_stream_verdicts_total", &[("model", model)]),
        );
    }
}
