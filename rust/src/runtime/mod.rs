//! PJRT runtime (DESIGN.md S15): loads the JAX-AOT'd HLO-text artifacts and
//! executes them on the XLA CPU client via the `xla` crate.
//!
//! This is the session architecture's L3↔L2 bridge: python lowered the
//! quantized Pallas inference graphs once (`make artifacts`); this module
//! loads `artifacts/<model>_quant_b<N>.hlo.txt`, compiles each once, and
//! serves executions from Rust with **no Python anywhere near the request
//! path**. One compiled executable per (model, batch) variant.
//!
//! Roles in the reproduction:
//! * **numerical oracle** — the golden path the native engines are checked
//!   against (`tests/integration_artifacts.rs`);
//! * **host serving backend** — `api::Session::builder(...).engine(Engine::Pjrt)`
//!   routes coordinator traffic onto the AOT'd executables.
//!
//! The `xla` crate comes from the build image (not crates.io) and is gated
//! behind the **`pjrt` feature** (see rust/Cargo.toml for how to wire the
//! vendored crate in): without it this module compiles a stub whose `load`
//! returns a clear error, so the rest of the crate (engine, interpreter,
//! coordinator, sim) builds and tests on machines without the XLA
//! toolchain.
//!
//! Gotchas inherited from the image (see /opt/xla-example/README.md): HLO
//! **text** interchange only — serialized protos from jax ≥ 0.5 carry
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; lowering used
//! `return_tuple=True`, so results unwrap with `to_tuple1`.

pub mod oracle;

use anyhow::{bail, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;

use crate::tensor::quant::QParams;

/// A compiled (model, batch) executable.
pub struct PjrtExecutable {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub in_len: usize,
    pub out_len: usize,
}

/// PJRT-backed engine: a set of batch-variant executables for one model.
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
pub struct PjrtEngine {
    pub model: String,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    /// Sorted by batch size ascending.
    variants: Vec<PjrtExecutable>,
    pub input_qparams: QParams,
    pub output_qparams: QParams,
    in_len: usize,
    out_len: usize,
    /// Per-sample input dims (the HLO input is `[batch, ..sample_dims]`).
    sample_dims: Vec<usize>,
}

impl PjrtEngine {
    /// Load every `artifacts/<model>_quant_b*.hlo.txt` variant.
    ///
    /// Quantization params come from the `.mfb` container (the HLO operates
    /// purely in the quantized int8 domain).
    #[cfg(feature = "pjrt")]
    pub fn load(artifacts: &std::path::Path, model: &str) -> Result<PjrtEngine> {
        let mfb = crate::format::mfb::MfbModel::load(artifacts.join(format!("{model}.mfb")))?;
        let in_len: usize = mfb.input_shape().iter().product();
        let out_len: usize = mfb.output_shape().iter().product();

        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut variants = Vec::new();
        for entry in std::fs::read_dir(artifacts).context("read artifacts dir")? {
            let path = entry?.path();
            let name = path.file_name().unwrap_or_default().to_string_lossy().to_string();
            let prefix = format!("{model}_quant_b");
            if let Some(rest) = name.strip_prefix(&prefix) {
                if let Some(bs) = rest.strip_suffix(".hlo.txt") {
                    let batch: usize = bs.parse().with_context(|| format!("batch in {name}"))?;
                    let proto = xla::HloModuleProto::from_text_file(
                        path.to_str().context("non-utf8 path")?,
                    )
                    .with_context(|| format!("parse HLO text {name}"))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = client.compile(&comp).with_context(|| format!("compile {name}"))?;
                    variants.push(PjrtExecutable { exe, batch, in_len, out_len });
                }
            }
        }
        if variants.is_empty() {
            bail!("no {model}_quant_b*.hlo.txt artifacts found in {}", artifacts.display());
        }
        variants.sort_by_key(|v| v.batch);
        Ok(PjrtEngine {
            model: model.to_string(),
            client,
            variants,
            input_qparams: mfb.input_qparams(),
            output_qparams: mfb.output_qparams(),
            in_len,
            out_len,
            sample_dims: mfb.input_shape(),
        })
    }

    /// Stub for builds without the XLA runtime.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(_artifacts: &std::path::Path, model: &str) -> Result<PjrtEngine> {
        bail!(
            "PJRT engine for {model:?} unavailable: this build lacks the `pjrt` feature \
             (the optional `xla` dependency); rebuild with `--features pjrt`"
        )
    }

    pub fn input_len(&self) -> usize {
        self.in_len
    }

    pub fn output_len(&self) -> usize {
        self.out_len
    }

    /// Available batch sizes (ascending).
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.variants.iter().map(|v| v.batch).collect()
    }

    /// Smallest variant that fits `n` samples (or the largest available).
    pub fn variant_for(&self, n: usize) -> &PjrtExecutable {
        self.variants.iter().find(|v| v.batch >= n).unwrap_or(self.variants.last().unwrap())
    }

    /// Execute a batch of quantized samples (`inputs.len() == n * in_len`),
    /// writing `n * out_len` values into `out`.
    ///
    /// Samples are padded up to the executable's batch size (extra rows are
    /// discarded) — the dynamic batcher upstream aims to fill variants.
    /// The XLA FFI boundary stages data through literals, so unlike the
    /// native engines this path does allocate internally.
    #[cfg(feature = "pjrt")]
    pub fn execute_batch_into(&self, inputs: &[i8], n: usize, out: &mut [i8]) -> Result<()> {
        if inputs.len() != n * self.in_len {
            bail!("batch input length {} != {} * {}", inputs.len(), n, self.in_len);
        }
        if out.len() != n * self.out_len {
            bail!("batch output length {} != {} * {}", out.len(), n, self.out_len);
        }
        let mut done = 0usize;
        while done < n {
            let var = self.variant_for(n - done);
            let take = (n - done).min(var.batch);
            let mut chunk = vec![0i8; var.batch * self.in_len];
            chunk[..take * self.in_len]
                .copy_from_slice(&inputs[done * self.in_len..(done + take) * self.in_len]);
            // i8 is ArrayElement but not NativeType in xla 0.1.6, so build
            // the literal via create_from_shape + copy_raw_from
            let shape: Vec<usize> = std::iter::once(var.batch)
                .chain(self.sample_dims.iter().copied())
                .collect();
            let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::S8, &shape);
            lit.copy_raw_from(&chunk)?;
            let result = var.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            let tuple = result.to_tuple1()?;
            let vals = tuple.to_vec::<i8>()?;
            out[done * self.out_len..(done + take) * self.out_len]
                .copy_from_slice(&vals[..take * self.out_len]);
            done += take;
        }
        Ok(())
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn execute_batch_into(&self, _inputs: &[i8], _n: usize, _out: &mut [i8]) -> Result<()> {
        bail!("PJRT execution unavailable without the `pjrt` feature")
    }

    /// Execute a batch, allocating the output (convenience).
    pub fn execute_batch(&self, inputs: &[i8], n: usize) -> Result<Vec<i8>> {
        let mut out = vec![0i8; n * self.out_len];
        self.execute_batch_into(inputs, n, &mut out)?;
        Ok(out)
    }

    /// Quantized single-sample predict (oracle convenience).
    pub fn predict_q(&self, input: &[i8]) -> Result<Vec<i8>> {
        self.execute_batch(input, 1)
    }

    #[cfg(feature = "pjrt")]
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn platform(&self) -> String {
        "unavailable (built without `pjrt`)".to_string()
    }
}

#[cfg(test)]
mod tests {
    // PJRT tests require built artifacts; they live in
    // rust/tests/integration_artifacts.rs so `cargo test --lib` stays
    // hermetic.
}
