//! Golden-path cross-checks (DESIGN.md S15).
//!
//! Three implementations of the same quantized model must agree:
//!
//! 1. the JAX/Pallas graph (captured in the golden `.bin` vectors and in
//!    the AOT'd HLO executed by [`super::PjrtEngine`]);
//! 2. the native MicroFlow engine (bit-exact — same float-scale epilogue);
//! 3. the TFLM-like interpreter (within ±1 output unit — fixed-point
//!    arithmetic; the paper's Sec. 6.2.1 observation).
//!
//! These functions are the assertion helpers used by
//! `tests/integration_artifacts.rs` and the `microflow verify` CLI.

use anyhow::{bail, Result};

use crate::format::golden::Golden;

/// Result of comparing an engine against golden vectors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Agreement {
    pub n_outputs: usize,
    pub exact: usize,
    pub within_one: usize,
    pub max_abs_diff: i32,
}

impl Agreement {
    pub fn is_bit_exact(&self) -> bool {
        self.exact == self.n_outputs
    }

    pub fn is_within_one(&self) -> bool {
        self.within_one == self.n_outputs
    }
}

/// Compare a predictor's outputs against golden vectors.
pub fn check_against_golden(
    golden: &Golden,
    mut predict: impl FnMut(&[i8]) -> Result<Vec<i8>>,
) -> Result<Agreement> {
    let mut agg =
        Agreement { n_outputs: 0, exact: 0, within_one: 0, max_abs_diff: 0 };
    for i in 0..golden.n {
        let out = predict(golden.input(i))?;
        let want = golden.output(i);
        if out.len() != want.len() {
            bail!("sample {i}: output length {} != golden {}", out.len(), want.len());
        }
        for (a, b) in out.iter().zip(want) {
            let d = (*a as i32 - *b as i32).abs();
            agg.n_outputs += 1;
            if d == 0 {
                agg.exact += 1;
            }
            if d <= 1 {
                agg.within_one += 1;
            }
            agg.max_abs_diff = agg.max_abs_diff.max(d);
        }
    }
    Ok(agg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn golden2() -> Golden {
        Golden {
            n: 2,
            in_shape: vec![2],
            out_shape: vec![2],
            x: vec![1, 2, 3, 4],
            y: vec![10, 20, 30, 40],
        }
    }

    #[test]
    fn exact_match_detected() {
        let g = golden2();
        let a = check_against_golden(&g, |x| Ok(x.iter().map(|v| v * 10).collect())).unwrap();
        assert!(a.is_bit_exact());
        assert_eq!(a.max_abs_diff, 0);
    }

    #[test]
    fn off_by_one_detected() {
        let g = golden2();
        let a = check_against_golden(&g, |x| {
            Ok(x.iter().map(|v| v * 10 + 1).collect())
        })
        .unwrap();
        assert!(!a.is_bit_exact());
        assert!(a.is_within_one());
        assert_eq!(a.max_abs_diff, 1);
    }

    #[test]
    fn length_mismatch_is_error() {
        let g = golden2();
        assert!(check_against_golden(&g, |_| Ok(vec![0i8; 3])).is_err());
    }
}
