//! Bench harness (DESIGN.md S21) — criterion is unavailable offline, so
//! this provides what the figure/table benches need, matching the paper's
//! own protocol: N timed iterations (default 100), median + 95% interval
//! (Sec. 6.2.3).

use std::time::Instant;

use crate::util::stats::Summary;
use crate::util::{fmt_time, Prng};

/// Time `iters` runs of `f` (after `warmup` runs) and summarize seconds.
pub fn time_iters<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::from(&samples)
}

/// Paper protocol: 100 iterations, median + 95% interval.
pub fn paper_protocol<F: FnMut()>(f: F) -> Summary {
    time_iters(3, 100, f)
}

/// One printed bench line: `name  median [p2.5, p97.5]`.
pub fn report_line(name: &str, s: &Summary) -> String {
    format!(
        "{name:40} median {:>12} [{} .. {}]",
        fmt_time(s.median),
        fmt_time(s.p2_5),
        fmt_time(s.p97_5)
    )
}

/// Deterministic random quantized inputs for kernel benches.
pub fn random_inputs(seed: u64, n: usize) -> Vec<i8> {
    Prng::new(seed).i8_vec(n)
}

/// Guard against the optimizer deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// True when `MICROFLOW_BENCH_SMOKE` is set: benches run one iteration
/// per shape (the CI layout-regression gate) and write their JSON
/// artifacts under a `.smoke` name so the tracked cross-PR perf trail
/// only ever holds real-run medians.
pub fn smoke_mode() -> bool {
    std::env::var_os("MICROFLOW_BENCH_SMOKE").is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_summary_has_iters() {
        let s = time_iters(1, 10, || {
            black_box((0..100).sum::<u64>());
        });
        assert_eq!(s.n, 10);
        assert!(s.median >= 0.0);
        assert!(s.p2_5 <= s.p97_5);
    }

    #[test]
    fn report_line_contains_name() {
        let s = Summary::from(&[0.001, 0.002, 0.003]);
        let line = report_line("demo", &s);
        assert!(line.contains("demo") && line.contains("median"));
    }
}
