//! **End-to-end serving driver** (experiment E10 in DESIGN.md — the
//! session's mandated e2e validation): load the real (trained, quantized,
//! AOT-compiled) speech-command model and serve batched requests through
//! the full stack, reporting latency and throughput.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_keywords
//! ```
//!
//! The run exercises every layer: the MFB container and compiler (L3
//! substrate), the MicroFlow engine AND the PJRT executable compiled from
//! the JAX/Pallas graph (L2/L1 artifacts), the dynamic batcher, worker
//! pool and metrics (L3 coordinator). An open-loop Poisson client drives
//! it with real test-set spectrograms, and the output classes are checked
//! against the dataset labels (accuracy must match the Table-5 level).
//! Backend 5 then pushes chunked audio frames over the v3 streaming wire
//! protocol (MFR3) and asserts every pulsed verdict bit-exact against the
//! one-shot path. Results are recorded in EXPERIMENTS.md §E10.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use microflow::api::{Engine, ReplicaFactory, Session, SessionCache};
use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::compiler::PulsePlan;
use microflow::coordinator::{
    AutoscalePolicy, Client, Fleet, Ingress, PoolSpec, QosClass, QosProfile, Request, Router,
    Server, ServerConfig, StreamHost, StreamHostConfig, Ticket,
};
use microflow::eval::accuracy::argmax;
use microflow::format::mds::MdsDataset;
use microflow::format::mfb::MfbModel;
use microflow::util::Prng;

const REQUESTS: usize = 1000;
const RATE_RPS: f64 = 400.0;

/// Open-loop Poisson load over any submit endpoint (`Server` and `Fleet`
/// both take a typed `Request` and answer with a `Ticket`), tallying
/// argmax accuracy against the dataset labels. Requests carry a
/// deterministic class blend — 3 interactive : 1 bulk — so class-aware
/// fleets route and report per class. The caller prints its own metrics
/// snapshot.
fn drive_load(
    name: &str,
    qp: microflow::tensor::quant::QParams,
    submit: impl Fn(Request) -> Result<Ticket>,
    ds: &MdsDataset,
    requests: usize,
    rate: f64,
) -> Result<f64> {
    let mut rng = Prng::new(7);
    let mut pending = Vec::with_capacity(requests);
    let t0 = Instant::now();
    for i in 0..requests {
        let idx = i % ds.n;
        let q = qp.quantize_slice(ds.sample(idx));
        let class = if i % 4 == 3 { QosClass::Bulk } else { QosClass::Interactive };
        pending.push((idx, submit(Request::new(q).with_class(class))?));
        std::thread::sleep(Duration::from_secs_f64(rng.exp(rate)));
    }
    let mut hits = 0usize;
    for (idx, ticket) in pending {
        let out = ticket.wait()?;
        if argmax(&out) as i32 == ds.class(idx) {
            hits += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let acc = hits as f64 / requests as f64;
    println!(
        "[{name}] wall {:.2}s | offered {:.0} rps | achieved {:.0} rps | accuracy {:.1}%",
        wall,
        rate,
        requests as f64 / wall,
        acc * 100.0
    );
    Ok(acc)
}

fn drive(name: &str, server: &Server, ds: &MdsDataset, requests: usize, rate: f64) -> Result<f64> {
    let acc = drive_load(name, server.input_qparams(), |r| server.submit(r), ds, requests, rate)?;
    println!("[{name}] {}", server.metrics.snapshot());
    Ok(acc)
}

/// Same driver over a fleet: dispatch picks the best profile match, then
/// the least-loaded pool, per request; per-pool per-class metrics land in
/// the snapshot.
fn drive_fleet(name: &str, fleet: &Fleet, ds: &MdsDataset, requests: usize, rate: f64) -> Result<f64> {
    let acc = drive_load(name, fleet.input_qparams(), |r| fleet.submit(r), ds, requests, rate)?;
    print!("[{name}] {}", fleet.snapshot());
    Ok(acc)
}

fn main() -> Result<()> {
    let art = microflow::artifacts_dir();
    anyhow::ensure!(art.join("speech.mfb").exists(), "run `make artifacts` first");
    let ds = MdsDataset::load(art.join("speech_test.mds"))?;
    println!(
        "speech command serving: {} test spectrograms ({}x{}), {REQUESTS} requests @ ~{RATE_RPS} rps\n",
        ds.n, ds.sample_shape[0], ds.sample_shape[1]
    );

    // --- backend 1: native MicroFlow sessions, 2 replicas ---
    let mfb_path = art.join("speech.mfb");
    let sessions: Vec<Session> = (0..2)
        .map(|_| Session::builder(&mfb_path).engine(Engine::MicroFlow).build())
        .collect::<Result<_>>()?;
    let mut cfg = ServerConfig::default();
    cfg.batcher.max_batch = 8;
    cfg.batcher.max_wait = Duration::from_millis(2);
    let server = Server::start(sessions, cfg)?;
    let acc_native = drive("microflow x2", &server, &ds, REQUESTS, RATE_RPS)?;
    server.shutdown();

    // --- backend 2: the JAX-AOT'd HLO on PJRT (batch-8 executable) ---
    // optional build feature: on default builds only the native path runs;
    // on a pjrt build any load failure is a real failure
    if cfg!(feature = "pjrt") {
        println!();
        let sessions = vec![Session::builder(&mfb_path).engine(Engine::Pjrt).build()?];
        let server = Server::start(sessions, cfg)?;
        let acc_pjrt = drive("pjrt b8    ", &server, &ds, REQUESTS, RATE_RPS)?;
        server.shutdown();

        // the two serving paths must agree on accuracy (same quantized graph)
        anyhow::ensure!(
            (acc_native - acc_pjrt).abs() < 0.01,
            "native ({acc_native}) and PJRT ({acc_pjrt}) accuracy diverged"
        );
    } else {
        println!("\npjrt backend: skipped — built without the `pjrt` feature");
    }

    // --- backend 3: a heterogeneous fleet — native pool (low latency,
    //     Interactive-preferred) + interpreter pool (the TFLM-style
    //     baseline as Bulk capacity; on a pjrt build, swap in a PJRT pool
    //     for bulk throughput). Class-aware dispatch sends the interactive
    //     share to the native pool and the bulk share to the interpreter.
    //     Replica sessions build through the warm cache: one compile, N
    //     replicas.
    println!();
    let cache = Arc::new(SessionCache::new());
    // same batcher as the plain backends, plus the fleet's per-replica
    // adaptive tuning
    let fleet_cfg = ServerConfig { adaptive: true, ..cfg };
    let native_pool: Vec<Session> = (0..2)
        .map(|i| {
            Session::builder(&mfb_path)
                .engine(Engine::MicroFlow)
                .label(format!("native/{i}"))
                .cache(&cache)
                .build()
        })
        .collect::<Result<_>>()?;
    let interp_pool = vec![Session::builder(&mfb_path)
        .engine(Engine::Interp)
        .label("interp/0")
        .cache(&cache)
        .build()?];
    let fleet = Fleet::start(vec![
        PoolSpec::new("native", native_pool)
            .config(fleet_cfg)
            .profile(QosProfile::for_engine(Engine::MicroFlow)),
        PoolSpec::new("interp", interp_pool)
            .config(fleet_cfg)
            .profile(QosProfile::for_engine(Engine::Interp)),
    ])?;
    println!(
        "fleet: {} replicas in 2 pools (warm cache: {} hits / {} misses)",
        fleet.replicas(),
        cache.hits(),
        cache.misses()
    );
    let acc_fleet = drive_fleet("fleet      ", &fleet, &ds, REQUESTS, RATE_RPS)?;
    let snap = fleet.snapshot();
    anyhow::ensure!(
        snap.totals.completed == REQUESTS as u64 && snap.totals.errors == 0,
        "fleet lost requests: {snap}"
    );
    fleet.shutdown();
    // the bulk share routes to the interp pool by class, and the interp
    // engine may flip argmax on near-ties (±1 per element) — so hold the
    // fleet to the same absolute quality bar, not exact parity with the
    // all-native run
    anyhow::ensure!(acc_fleet > 0.80, "fleet serving accuracy collapsed: {acc_fleet}");

    // --- backend 4: an elastic native pool under the SLO-driven
    //     autoscaler. The pool starts at one replica; a burst (every
    //     request carrying a tight deadline) breaches the SLO and the
    //     controller grows the pool through the warm cache (no recompile);
    //     the idle phase after the burst shrinks it back to the floor via
    //     graceful drain. Replica trajectory is printed per tick.
    println!();
    let factory = Arc::new(
        ReplicaFactory::new(&mfb_path, Engine::MicroFlow)
            .cache(&cache)
            .label_prefix("elastic"),
    );
    let policy = AutoscalePolicy::new(1, 3)
        .slo_p95(Duration::from_millis(20))
        .idle_ticks_down(2)
        .cooldown_ticks(1);
    let elastic = Fleet::start(vec![PoolSpec::new("elastic", vec![factory.provision()?])
        .config(fleet_cfg)
        .autoscale(policy, Arc::clone(&factory))])?;
    let qp = elastic.input_qparams();
    let mut trajectory = vec![elastic.snapshot().per_pool[0].live_replicas()];
    let mut elastic_pending = Vec::new();
    // bursty phase: chunks of back-to-back submits with a control tick
    // after each chunk. Two probe requests per chunk carry an
    // already-expired deadline — guaranteed sheds, so the burst breaches
    // the SLO deterministically on any machine (the p95 rule additionally
    // fires wherever one replica really is too slow for the burst).
    for chunk in 0..8 {
        for i in 0..25 {
            let idx = (chunk * 25 + i) % ds.n;
            let q = qp.quantize_slice(ds.sample(idx));
            let req = Request::interactive(q).with_deadline_in(Duration::from_millis(250));
            elastic_pending.push((idx, elastic.submit(req)?));
        }
        for _ in 0..2 {
            let q = qp.quantize_slice(ds.sample(chunk % ds.n));
            let probe = Request::interactive(q).with_deadline(Instant::now());
            elastic_pending.push((chunk % ds.n, elastic.submit(probe)?));
        }
        for r in elastic.tick() {
            trajectory.push(r.live_replicas);
            if r.acted() {
                println!("[autoscale] {r}");
            }
        }
    }
    let mut hits = 0usize;
    let mut late_or_shed = 0usize;
    let total = elastic_pending.len();
    for (idx, ticket) in elastic_pending {
        match ticket.wait() {
            Ok(out) => {
                if argmax(&out) as i32 == ds.class(idx) {
                    hits += 1;
                }
            }
            // a shed request is an SLO casualty, not a lost request: its
            // ticket resolves with an explicit error
            Err(e) if format!("{e:#}").contains("shed") => late_or_shed += 1,
            Err(e) => return Err(e),
        }
    }
    // idle phase: drain done, ticks walk the pool back to the floor
    for _ in 0..10 {
        for r in elastic.tick() {
            trajectory.push(r.live_replicas);
            if r.acted() {
                println!("[autoscale] {r}");
            }
        }
    }
    let snap = elastic.snapshot();
    println!(
        "[elastic] replica trajectory {trajectory:?} | {hits}/{total} correct, {late_or_shed} shed\n{snap}"
    );
    let peak = *trajectory.iter().max().unwrap();
    anyhow::ensure!(peak > 1, "the burst never scaled the pool up: {trajectory:?}");
    anyhow::ensure!(
        trajectory.last() == Some(&1),
        "the idle phase never shrank the pool back: {trajectory:?}"
    );
    let resolved =
        snap.totals.completed + snap.totals.shed + snap.totals.cancelled;
    anyhow::ensure!(
        resolved == snap.totals.submitted && snap.totals.errors == 0,
        "elastic pool lost requests: {snap}"
    );
    elastic.shutdown();

    // --- backend 5: streaming over the v3 wire protocol (MFR3). Audio
    //     arrives one spectrogram row per push through the TCP ingress;
    //     the coordinator's streaming lane runs the pulsed incremental
    //     path, and every verdict is asserted bit-exact against a
    //     one-shot native run over the same materialized window. The
    //     speech model is used when its geometry admits a pulse plan
    //     (valid padding, window-covering kernels); otherwise a
    //     synthetic streaming model stands in so the wire path is
    //     always exercised.
    println!();
    let speech = MfbModel::load(&mfb_path)?;
    let (stream_name, stream_model) = {
        let compiled = CompiledModel::compile(&speech, CompileOptions::default())?;
        match PulsePlan::plan(&compiled) {
            Ok(_) => ("speech", speech),
            Err(e) => {
                println!(
                    "[stream] speech model is not pulse-streamable ({e:#}); \
                     using a synthetic streaming stand-in"
                );
                ("synth-stream", microflow::synth::stream_conv_chain(&mut Prng::new(42), 2))
            }
        }
    };
    let compiled = Arc::new(CompiledModel::compile(&stream_model, CompileOptions::default())?);
    let plan = PulsePlan::plan(&compiled)?;
    println!(
        "[stream] model {stream_name}: window {} rows x {} bytes, verdict every {} frame(s), \
         pulsed work {:.0}% of full recompute",
        plan.window_rows,
        plan.frame_len,
        plan.pulse_frames,
        plan.savings_ratio(&compiled) * 100.0
    );
    let host = Arc::new(StreamHost::start(Arc::clone(&compiled), StreamHostConfig::default())?);
    let mut router = Router::new();
    router.add_stream_host(stream_name, Arc::clone(&host));
    let ingress = Ingress::start("127.0.0.1:0", Arc::new(router))?;
    let mut client = Client::connect(ingress.addr)?;
    let id = client.open_stream(stream_name)?;

    // one-shot oracle + the frame source (real spectrogram rows when the
    // speech model streams, deterministic noise for the stand-in)
    let mut one_shot = Session::builder(&stream_model).engine(Engine::MicroFlow).build()?;
    let window_len = plan.window_rows * plan.frame_len;
    let frames = plan.window_rows * 2 + plan.pulse_frames * 2;
    let need = frames * plan.frame_len;
    let mut source: Vec<i8> = if stream_name == "speech" {
        let qp = one_shot.input_qparams();
        let mut s = Vec::with_capacity(need + window_len);
        let mut i = 0usize;
        while s.len() < need {
            s.extend(qp.quantize_slice(ds.sample(i % ds.n)));
            i += 1;
        }
        s
    } else {
        Prng::new(1234).i8_vec(need)
    };
    source.truncate(need);

    let mut history: Vec<i8> = Vec::new();
    let mut stream_verdicts = 0usize;
    for frame in source.chunks_exact(plan.frame_len) {
        history.extend_from_slice(frame);
        if let Some(v) = client.push_frame(id, frame)? {
            let expect = one_shot.run(&history[history.len() - window_len..])?;
            anyhow::ensure!(
                v == expect,
                "streamed verdict diverged from the one-shot path at frame {}",
                history.len() / plan.frame_len
            );
            stream_verdicts += 1;
        }
    }
    let counters = client.close_stream(id)?;
    ingress.shutdown();
    println!(
        "[stream] {frames} frames pushed, {stream_verdicts} verdicts, all bit-exact vs one-shot \
         | submitted {} completed {} shed {} cancelled {} failed {}",
        counters.submitted, counters.completed, counters.shed, counters.cancelled, counters.failed
    );
    anyhow::ensure!(stream_verdicts >= 2, "pulse cadence never fired twice over the wire");
    anyhow::ensure!(
        counters.identity_holds() && counters.submitted == frames as u64,
        "stream lifecycle identity broken: {counters:?}"
    );

    anyhow::ensure!(acc_native > 0.80, "serving accuracy collapsed: {acc_native}");
    println!("\nserve_keywords OK: all layers compose (engine == AOT graph, accuracy {:.1}%)", acc_native * 100.0);
    Ok(())
}
