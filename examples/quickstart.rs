//! Quickstart: the 60-second tour of the public API.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Builds a session for each of the three engines through the one entry
//! point (`microflow::api::Session`), runs a few inferences, cross-checks
//! them against the JAX golden vectors, and prints the static memory plan
//! — the whole paper in one screen.

use anyhow::Result;
use microflow::api::{Engine, Session};
use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::format::golden::Golden;
use microflow::format::mfb::MfbModel;
use microflow::runtime::oracle::check_against_golden;
use microflow::util::fmt_kb;

fn main() -> Result<()> {
    let art = microflow::artifacts_dir();
    anyhow::ensure!(art.join("sine.mfb").exists(), "run `make artifacts` first");
    let mfb_path = art.join("sine.mfb");

    // 1. one builder, three engines (paper Sec. 3.3: parse -> preprocess
    //    -> plan happens inside the MicroFlow session's build)
    let mut engine = Session::builder(&mfb_path).engine(Engine::MicroFlow).build()?;
    println!("== MicroFlow session (sine predictor) ==");
    println!("engine: {}", engine.engine());
    println!(
        "signature: {:?} {:?} -> {:?} {:?}",
        engine.signature().input.shape,
        engine.input_qparams(),
        engine.signature().output.shape,
        engine.output_qparams(),
    );

    // 2. compiled-plan introspection stays on the compiler layer
    let model = MfbModel::load(&mfb_path)?;
    let compiled = CompiledModel::compile(&model, CompileOptions::default())?;
    println!("steps: {}", compiled.steps.len());
    println!("MACs/inference: {}", compiled.total_macs());
    println!("weights+consts: {}", fmt_kb(compiled.weight_bytes()));

    // 3. static memory plan (Sec. 4.2): two ping-pong buffers, no heap on
    //    the hot path
    let m = &compiled.memory;
    println!(
        "static memory plan: peak {} at step {} (buffers {} + {} + scratch {})",
        fmt_kb(m.peak),
        m.peak_step,
        fmt_kb(m.buf_a),
        fmt_kb(m.buf_b),
        fmt_kb(m.scratch),
    );

    // 4. run inference: sin(x) for a few x
    println!("\n x      sin(x)   microflow");
    for x in [0.5f32, 1.0, 2.0, 4.0, 5.5] {
        let y = engine.run_f32(&[x])?;
        println!("{x:4.1}   {:+.4}  {:+.4}", x.sin(), y[0]);
    }

    // 5. golden cross-check: JAX oracle vs all three engines
    let golden = Golden::load(art.join("sine_golden.bin"))?;
    let a = check_against_golden(&golden, |x| engine.run(x))?;
    println!("\nvs JAX golden vectors:");
    println!("  microflow engine  : exact {}/{}", a.exact, a.n_outputs);

    let mut interp = Session::builder(&mfb_path).engine(Engine::Interp).build()?;
    let b = check_against_golden(&golden, |x| interp.run(x))?;
    println!(
        "  tflm interpreter  : exact {}/{} (max |Δ| = {} — the paper's ±1)",
        b.exact, b.n_outputs, b.max_abs_diff
    );

    // PJRT is an optional build feature: skip on default builds, but on a
    // pjrt build a load failure is a real failure (don't mask bad HLO)
    if cfg!(feature = "pjrt") {
        let mut pjrt = Session::builder(&mfb_path).engine(Engine::Pjrt).build()?;
        let c = check_against_golden(&golden, |x| pjrt.run(x))?;
        println!("  pjrt (AOT HLO)    : exact {}/{}", c.exact, c.n_outputs);
    } else {
        println!("  pjrt (AOT HLO)    : skipped — built without the `pjrt` feature");
    }

    println!("\nquickstart OK");
    Ok(())
}
