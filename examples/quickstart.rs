//! Quickstart: the 60-second tour of the public API.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Loads the sine predictor, compiles it with the MicroFlow compiler, runs
//! a few inferences, cross-checks the TFLM-like interpreter and the PJRT
//! (JAX-AOT) oracle, and prints the static memory plan — the whole paper
//! in one screen.

use anyhow::Result;
use microflow::compiler::plan::CompileOptions;
use microflow::engine::MicroFlowEngine;
use microflow::format::golden::Golden;
use microflow::interp::resolver::OpResolver;
use microflow::interp::Interpreter;
use microflow::runtime::oracle::check_against_golden;
use microflow::runtime::PjrtEngine;
use microflow::util::fmt_kb;

fn main() -> Result<()> {
    let art = microflow::artifacts_dir();
    anyhow::ensure!(art.join("sine.mfb").exists(), "run `make artifacts` first");

    // 1. compile the model (paper Sec. 3.3: parse -> preprocess -> plan)
    let engine = MicroFlowEngine::load(art.join("sine.mfb"), CompileOptions::default())?;
    println!("== MicroFlow engine (sine predictor) ==");
    println!("steps: {}", engine.compiled().steps.len());
    println!("MACs/inference: {}", engine.compiled().total_macs());
    println!("weights+consts: {}", fmt_kb(engine.compiled().weight_bytes()));

    // 2. static memory plan (Sec. 4.2): two ping-pong buffers, no heap on
    //    the hot path
    let m = &engine.compiled().memory;
    println!(
        "static memory plan: peak {} at step {} (buffers {} + {} + scratch {})",
        fmt_kb(m.peak),
        m.peak_step,
        fmt_kb(m.buf_a),
        fmt_kb(m.buf_b),
        fmt_kb(m.scratch),
    );

    // 3. run inference: sin(x) for a few x
    println!("\n x      sin(x)   microflow");
    for x in [0.5f32, 1.0, 2.0, 4.0, 5.5] {
        let y = engine.predict_f32(&[x]);
        println!("{x:4.1}   {:+.4}  {:+.4}", x.sin(), y[0]);
    }

    // 4. golden cross-check: JAX oracle vs all three engines
    let golden = Golden::load(art.join("sine_golden.bin"))?;
    let a = check_against_golden(&golden, |x| Ok(engine.predict(x)))?;
    println!("\nvs JAX golden vectors:");
    println!("  microflow engine  : exact {}/{}", a.exact, a.n_outputs);

    let bytes = std::fs::read(art.join("sine.mfb"))?;
    let mut interp = Interpreter::new(&bytes, &OpResolver::with_all_kernels())?;
    let b = check_against_golden(&golden, |x| interp.invoke(x))?;
    println!(
        "  tflm interpreter  : exact {}/{} (max |Δ| = {} — the paper's ±1)",
        b.exact, b.n_outputs, b.max_abs_diff
    );

    let pjrt = PjrtEngine::load(&art, "sine")?;
    let c = check_against_golden(&golden, |x| pjrt.predict_q(x))?;
    println!("  pjrt (AOT HLO)    : exact {}/{} on {}", c.exact, c.n_outputs, pjrt.platform());

    println!("\nquickstart OK");
    Ok(())
}
