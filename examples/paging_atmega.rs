//! Paging demo (paper Sec. 4.3, Fig. 6 + experiment E8 in DESIGN.md):
//! running a dense model in 2 kB of RAM.
//!
//! Reproduces the paper's worked example — a 32-neuron fully connected
//! layer needs ~5 kB unpaged (impossible on an ATmega328) but only 163
//! bytes per page paged — then runs the real sine model through the paged
//! executor on the simulated ATmega328, proving (a) bit-identical outputs
//! and (b) the memory/time trade.

use anyhow::Result;
use microflow::api::Session;
use microflow::compiler::paging::PagePlan;
use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::format::mfb::MfbModel;
use microflow::sim::mcu::by_name;
use microflow::sim::{self, Engine};
use microflow::util::{fmt_kb, fmt_time};

fn main() -> Result<()> {
    println!("== Paper Sec. 4.3 worked example: FC 32x32 on ATmega328 (2 kB RAM) ==");
    let plan = PagePlan::for_fully_connected(32, 32);
    println!(
        "unpaged working set : {} (paper: ~5 kB -> stack overflow)",
        fmt_kb(plan.unpaged_bytes)
    );
    println!(
        "paged, per page     : {} bytes x {} pages (paper: 163 B)",
        plan.page_bytes, plan.pages
    );
    assert_eq!(plan.page_bytes, 163);

    let art = microflow::artifacts_dir();
    anyhow::ensure!(art.join("sine.mfb").exists(), "run `make artifacts` first");
    let model = MfbModel::load(art.join("sine.mfb"))?;
    let atmega = by_name("ATmega328").unwrap();

    println!("\n== sine predictor on the simulated ATmega328 ==");
    for paging in [false, true] {
        let compiled = CompiledModel::compile(&model, CompileOptions { paging, ..Default::default() })?;
        let fp = sim::memory_model::microflow_footprint(&compiled, atmega);
        let fit = sim::memory_model::fits(atmega, Engine::MicroFlow, fp);
        let t = sim::inference_seconds(&compiled, atmega, Engine::MicroFlow);
        println!(
            "paging={paging:5}  flash {:>9}  ram {:>9}  modeled time {:>10}  fits: {}",
            fmt_kb(fp.flash),
            fmt_kb(fp.ram),
            fmt_time(t),
            match fit {
                Ok(()) => "yes".to_string(),
                Err(e) => format!("NO ({e})"),
            }
        );
    }

    // bit-identical outputs regardless of paging (Sec. 4.3: a time/space
    // trade, never an accuracy trade) — both sessions through the builder
    let mut unpaged = Session::builder(&model).paging(false).build()?;
    let mut paged = Session::builder(&model).paging(true).build()?;
    let mut checked = 0;
    for q in -60..60 {
        let a = unpaged.run(&[q])?;
        let b = paged.run(&[q])?;
        assert_eq!(a, b, "paged output diverged at input {q}");
        checked += 1;
    }
    println!("\npaged vs unpaged: bit-identical on {checked} inputs ✓");

    // TFLM for contrast: no port for AVR at all (paper Sec. 6.2.2)
    println!(
        "TFLM on ATmega328: {}",
        match sim::memory_model::fits(
            atmega,
            Engine::Tflm,
            sim::memory_model::MemoryFootprint { flash: 0, ram: 0 }
        ) {
            Err(e) => format!("{e}"),
            Ok(()) => unreachable!(),
        }
    );
    Ok(())
}
