//! Fleet deployment report: every (model, MCU, engine) combination of the
//! paper's evaluation in one table — the Sec. 6 experience end to end.
//!
//! For each combination: does it fit (Flash/RAM/port availability), the
//! modeled inference time and the modeled energy. This regenerates the
//! *qualitative* layer of Fig. 9-11 / Table 6 (which engine runs where);
//! the per-figure benches print the quantitative series.

use anyhow::Result;
use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::format::mfb::MfbModel;
use microflow::interp::arena::ArenaPlan;
use microflow::sim::report::Table;
use microflow::sim::{self, Engine, MCUS};
use microflow::util::{fmt_energy_wh, fmt_kb, fmt_time};

fn main() -> Result<()> {
    let art = microflow::artifacts_dir();
    let mut table = Table::new(
        "fleet deployment matrix (model x MCU x engine)",
        &["model", "mcu", "engine", "flash", "ram", "fits", "time", "energy"],
    );

    for model_name in ["sine", "speech", "person"] {
        let path = art.join(format!("{model_name}.mfb"));
        anyhow::ensure!(path.exists(), "run `make artifacts` first");
        let model = MfbModel::load(&path)?;
        let arena = ArenaPlan::plan(&model)?;

        for mcu in MCUS.iter() {
            for engine in [Engine::MicroFlow, Engine::Tflm] {
                // on the smallest device MicroFlow switches paging on,
                // exactly as a user would (Sec. 4.3)
                let paging = engine == Engine::MicroFlow && mcu.ram_bytes <= 4 * 1024;
                let compiled = CompiledModel::compile(&model, CompileOptions { paging, ..Default::default() })?;
                let fp = match engine {
                    Engine::MicroFlow => sim::memory_model::microflow_footprint(&compiled, mcu),
                    Engine::Tflm => sim::memory_model::tflm_footprint(&model, &arena, mcu),
                };
                let fit = sim::memory_model::fits(mcu, engine, fp);
                let engine_s = match engine {
                    Engine::MicroFlow => {
                        if paging {
                            "microflow+pg"
                        } else {
                            "microflow"
                        }
                    }
                    Engine::Tflm => "tflm",
                };
                let (fits_s, time_s, energy_s) = match fit {
                    Ok(()) => (
                        "yes".to_string(),
                        fmt_time(sim::inference_seconds(&compiled, mcu, engine)),
                        fmt_energy_wh(sim::energy::inference_energy_wh(&compiled, mcu, engine)),
                    ),
                    Err(e) => (format!("NO: {e}"), "-".into(), "-".into()),
                };
                table.row(vec![
                    model_name.into(),
                    mcu.name.into(),
                    engine_s.into(),
                    fmt_kb(fp.flash),
                    fmt_kb(fp.ram),
                    fits_s,
                    time_s,
                    energy_s,
                ]);
            }
        }
    }
    sim::report::emit("mcu_fleet", &table);

    // the paper's headline qualitative claims, asserted:
    println!("checking paper claims ...");
    let sine = MfbModel::load(art.join("sine.mfb"))?;
    let compiled = CompiledModel::compile(&sine, CompileOptions { paging: true, ..Default::default() })?;
    let atmega = sim::mcu::by_name("ATmega328").unwrap();
    let fp = sim::memory_model::microflow_footprint(&compiled, atmega);
    assert!(
        sim::memory_model::fits(atmega, Engine::MicroFlow, fp).is_ok(),
        "sine must fit the 8-bit ATmega328 under MicroFlow (paper Sec. 6.2.2)"
    );
    assert!(
        !atmega.tflm_supported,
        "TFLM must not be available on the ATmega328"
    );
    println!("mcu_fleet OK");
    Ok(())
}
