"""L2 model-graph tests: shapes, parameter counts, pallas==ref equivalence
on whole models, dataset invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets as D
from compile import model as M
from compile.kernels import ref
from compile.quantize import ptq


def test_layer_shapes_sine():
    shapes = M.layer_shapes(M.sine_model())
    assert shapes == [(1,), (16,), (16,), (1,)]


def test_layer_shapes_speech():
    shapes = M.layer_shapes(M.speech_model())
    assert shapes[0] == (49, 40, 1)
    assert shapes[1] == (25, 20, 8)  # dwconv s2, mult 8
    assert shapes[2] == (4000,)
    assert shapes[-1] == (4,)


def test_layer_shapes_person():
    model = M.person_model()
    shapes = M.layer_shapes(model)
    assert shapes[0] == (96, 96, 1)
    assert shapes[1] == (48, 48, 8)
    # end of the conv stack: 3x3x256 before avgpool
    assert (3, 3, 256) in shapes
    assert shapes[-1] == (2,)
    # the paper counts 30 layers; ours is 31 including the explicit flatten
    assert len(model.layers) == 31


def test_person_param_count_in_paper_ballpark():
    n = M.param_count(M.person_model())
    # MobileNetV1 x0.25 (96x96, 2 classes): ~210k params -> ~210 kB int8
    assert 150_000 < n < 300_000, n


def test_speech_size_matches_paper_19kb():
    n = M.param_count(M.speech_model())
    assert 15_000 < n < 22_000, n  # paper: ~19 kB int8


@pytest.mark.parametrize("name", ["sine", "speech"])
def test_forward_quant_pallas_equals_ref_whole_model(name):
    model = M.MODELS[name]()
    params = M.init_params(model, seed=7)
    calib = {"sine": D.sine_train(64).x, "speech": D.speech_train(16).x}[name]
    qm = ptq(model, params, calib)
    test_x = calib[:4]
    gx = ref.quantize(jnp.asarray(test_x), qm.input_qparams.scale, qm.input_qparams.zero_point)
    a = M.forward_quant(qm, gx, backend="ref")
    b = M.forward_quant(qm, gx, backend="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_forward_float_batch_independence():
    """Per-sample results must not depend on batch composition."""
    model = M.speech_model()
    params = M.init_params(model, seed=9)
    x = D.speech_train(4).x
    full = np.asarray(M.forward_float(model, params, jnp.asarray(x)))
    single = np.asarray(M.forward_float(model, params, jnp.asarray(x[1:2])))
    np.testing.assert_allclose(full[1:2], single, rtol=1e-5, atol=1e-5)


def test_datasets_are_deterministic():
    a = D.speech_test(10, seed=11)
    b = D.speech_test(10, seed=11)
    np.testing.assert_array_equal(a.x, b.x)
    np.testing.assert_array_equal(a.y, b.y)
    c = D.speech_test(10, seed=12)
    assert not np.array_equal(a.x, c.x)


def test_dataset_shapes_and_sizes_match_paper():
    assert D.sine_test().n == 1000
    assert D.speech_test(5).x.shape[1:] == (49, 40, 1)
    assert D.person_test(5).x.shape[1:] == (96, 96, 1)
    assert D.SPEECH_TEST_N == 1236
    assert D.PERSON_TEST_N == 406


def test_sine_test_noise_band():
    ds = D.sine_test(500)
    noise = ds.y.ravel() - np.sin(ds.x.ravel())
    assert np.abs(noise).max() <= 0.1 + 1e-6
    assert np.abs(noise).mean() > 0.01  # actually noisy


def test_all_classes_present():
    sp = D.speech_test(400)
    assert set(np.unique(sp.y)) == {0, 1, 2, 3}
    pe = D.person_test(100)
    assert set(np.unique(pe.y)) == {0, 1}
