"""PTQ unit tests: quantization parameters, roundtrips, model PTQ sanity."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import datasets as D
from compile import model as M
from compile.kernels import ref
from compile.quantize import (
    QParams,
    activation_qparams,
    ptq,
    quantize_array,
    weight_qparams,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.floats(-3.0, 0.0), st.floats(0.0, 3.0))
def test_activation_qparams_cover_range(lo, hi):
    qp = activation_qparams(lo, hi)
    assert qp.scale > 0
    # zero must be exactly representable (zero_point lands on it)
    z_real = qp.dequantize(np.int8(np.clip(qp.zero_point, -128, 127)))
    assert abs(z_real) < 1e-6
    # endpoints quantize inside the int8 range within one step
    for v in (lo, hi):
        q = quantize_array(np.array([v], np.float32), qp)
        back = qp.dequantize(q)[0]
        assert abs(back - v) <= qp.scale + 1e-6


@given(st.integers(0, 2**31))
def test_weight_qparams_symmetric(seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.5, 64).astype(np.float32)
    qp = weight_qparams(w)
    assert qp.zero_point == 0
    q = quantize_array(w, qp)
    assert int(np.abs(q.astype(np.int32)).max()) <= 127
    err = np.abs(qp.dequantize(q) - w).max()
    assert err <= qp.scale / 2 + 1e-6


def test_quantize_array_matches_ref_quantize():
    rng = np.random.default_rng(3)
    vals = rng.normal(0, 1, 256).astype(np.float32)
    qp = QParams(0.0173, -7)
    a = quantize_array(vals, qp)
    b = np.asarray(ref.quantize(jnp.asarray(vals), qp.scale, qp.zero_point))
    np.testing.assert_array_equal(a, b)


def test_ptq_sine_end_to_end_quality():
    """PTQ'd sine model must stay close to the float model (paper Table 5
    regime: quantization costs little accuracy)."""
    model = M.sine_model()
    params = M.init_params(model, seed=0)
    # quick train so the function is non-trivial
    from compile import train as T

    params = T.train(model, D.sine_train(1000), steps=600, batch=64, lr=5e-3, log_every=0, log=lambda *a: None)
    qm = ptq(model, params, D.sine_train(256).x)
    xs = D.sine_test(200)
    f_out = np.asarray(M.forward_float(model, params, jnp.asarray(xs.x))).ravel()
    gx = ref.quantize(jnp.asarray(xs.x), qm.input_qparams.scale, qm.input_qparams.zero_point)
    q_out = np.asarray(M.forward_quant(qm, gx, backend="ref")).ravel()
    q_real = qm.output_qparams.dequantize(q_out)
    # quantization error bounded by a handful of output steps (per-layer
    # rounding compounds across the 3 FC layers; ~8 steps observed)
    assert np.abs(q_real - f_out).max() < 12 * qm.output_qparams.scale
    assert np.sqrt(np.mean((q_real - f_out) ** 2)) < 4 * qm.output_qparams.scale


def test_ptq_layer_stitching_is_consistent():
    """Adjacent layers must share qparams at the seam (out[i] == in[i+1])."""
    model = M.speech_model()
    params = M.init_params(model, seed=1)
    qm = ptq(model, params, D.speech_train(32).x)
    for a, b in zip(qm.layers, qm.layers[1:]):
        assert a["out"] == b["in"]


def test_ptq_bias_scale_is_product():
    model = M.sine_model()
    params = M.init_params(model, seed=2)
    qm = ptq(model, params, D.sine_train(64).x)
    for lq in qm.layers:
        if lq["wq"] is not None:
            want = float(np.float32(lq["in"].scale) * np.float32(lq["wq"].scale))
            assert abs(lq["bq"].scale - want) < 1e-12
            assert lq["bq"].zero_point == 0


def test_softmax_output_qparams_fixed():
    model = M.speech_model()
    params = M.init_params(model, seed=3)
    qm = ptq(model, params, D.speech_train(16).x)
    assert qm.output_qparams.scale == 1 / 256
    assert qm.output_qparams.zero_point == -128
