"""L1 correctness: Pallas kernels vs the pure-jnp oracle (bit-exact).

Hypothesis sweeps shapes, quantization parameters, strides, paddings and
fused activations — the CORE correctness signal for the compile path
(DESIGN.md deliverable (c): python side).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quantized as qk
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def qparams(draw, lo=0.005, hi=0.2):
    s = draw(st.floats(lo, hi))
    z = draw(st.integers(-20, 20))
    return float(np.float32(s)), z



def assert_quant_equal(r, p, msg=""):
    """Bit-equality up to FMA ties: XLA may fuse the float epilogue into an
    FMA inside pallas_call, flipping exact .5 ties vs the eager oracle
    (see test_qgemm_block_boundary_shapes). Ties are the only permitted
    deviation: |delta| <= 1 on < 0.5% of outputs."""
    r = np.asarray(r).astype(np.int32)
    p = np.asarray(p).astype(np.int32)
    d = np.abs(r - p)
    assert d.max() <= 1, f"{msg}: max diff {d.max()}"
    budget = max(2, int(0.005 * d.size))  # small arrays: allow a couple of ties
    assert (d > 0).sum() <= budget, f"{msg}: {(d > 0).sum()}/{d.size} mismatches"


arrays_i8 = lambda shape: st.builds(
    lambda seed: np.random.default_rng(seed).integers(-128, 128, shape).astype(np.int8),
    st.integers(0, 2**31),
)


@st.composite
def fc_case(draw):
    m = draw(st.integers(1, 9))
    k = draw(st.integers(1, 64))
    n = draw(st.integers(1, 24))
    seed = draw(st.integers(0, 2**31))
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (m, k)).astype(np.int8)
    w = rng.integers(-128, 128, (k, n)).astype(np.int8)
    b = rng.integers(-2000, 2000, (n,)).astype(np.int32)
    s_x, z_x = qparams(draw)
    s_w, z_w = qparams(draw)
    s_y, z_y = qparams(draw)
    act = draw(st.sampled_from(["none", "relu", "relu6"]))
    return x, w, b, dict(s_x=s_x, z_x=z_x, s_w=s_w, z_w=z_w, s_b=s_x * s_w, z_b=0,
                         s_y=s_y, z_y=z_y, act=act)


@given(fc_case())
def test_fully_connected_pallas_equals_ref(case):
    x, w, b, kw = case
    r = ref.fully_connected(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), **kw)
    p = qk.fully_connected(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), **kw)
    assert_quant_equal(r, p, "fc")


@st.composite
def conv_case(draw):
    n = 1
    h = draw(st.integers(3, 12))
    w_ = draw(st.integers(3, 12))
    cin = draw(st.integers(1, 4))
    cout = draw(st.integers(1, 6))
    kh = draw(st.integers(1, min(4, h)))
    kw_ = draw(st.integers(1, min(4, w_)))
    stride = (draw(st.integers(1, 2)), draw(st.integers(1, 2)))
    padding = draw(st.sampled_from(["same", "valid"]))
    seed = draw(st.integers(0, 2**31))
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (n, h, w_, cin)).astype(np.int8)
    f = rng.integers(-128, 128, (cout, kh, kw_, cin)).astype(np.int8)
    b = rng.integers(-1000, 1000, (cout,)).astype(np.int32)
    s_x, z_x = qparams(draw)
    s_f, z_f = qparams(draw)
    s_y, z_y = qparams(draw)
    act = draw(st.sampled_from(["none", "relu", "relu6"]))
    return x, f, b, dict(stride=stride, padding=padding, s_x=s_x, z_x=z_x, s_f=s_f,
                         z_f=z_f, s_b=s_x * s_f, z_b=0, s_y=s_y, z_y=z_y, act=act)


@given(conv_case())
def test_conv2d_pallas_equals_ref(case):
    x, f, b, kw = case
    r = ref.conv2d(jnp.asarray(x), jnp.asarray(f), jnp.asarray(b), **kw)
    p = qk.conv2d(jnp.asarray(x), jnp.asarray(f), jnp.asarray(b), **kw)
    assert_quant_equal(r, p, "conv2d")


@st.composite
def dw_case(draw):
    h = draw(st.integers(3, 10))
    w_ = draw(st.integers(3, 10))
    cin = draw(st.integers(1, 4))
    mult = draw(st.sampled_from([1, 2, 4, 8]))
    kh = draw(st.integers(1, min(4, h)))
    kw_ = draw(st.integers(1, min(4, w_)))
    stride = (draw(st.integers(1, 2)), draw(st.integers(1, 2)))
    padding = draw(st.sampled_from(["same", "valid"]))
    seed = draw(st.integers(0, 2**31))
    rng = np.random.default_rng(seed)
    cout = cin * mult
    x = rng.integers(-128, 128, (1, h, w_, cin)).astype(np.int8)
    w = rng.integers(-128, 128, (1, kh, kw_, cout)).astype(np.int8)
    b = rng.integers(-1000, 1000, (cout,)).astype(np.int32)
    s_x, z_x = qparams(draw)
    s_w, z_w = qparams(draw)
    s_y, z_y = qparams(draw)
    act = draw(st.sampled_from(["none", "relu", "relu6"]))
    return x, w, b, dict(stride=stride, padding=padding, depth_multiplier=mult,
                         s_x=s_x, z_x=z_x, s_w=s_w, z_w=z_w, s_b=s_x * s_w, z_b=0,
                         s_y=s_y, z_y=z_y, act=act)


@given(dw_case())
def test_depthwise_pallas_equals_ref(case):
    x, w, b, kw = case
    r = ref.depthwise_conv2d(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), **kw)
    p = qk.depthwise_conv2d(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), **kw)
    assert_quant_equal(r, p, "dwconv")


@st.composite
def pool_case(draw):
    k = draw(st.integers(1, 4))
    oh = draw(st.integers(1, 4))
    c = draw(st.integers(1, 6))
    seed = draw(st.integers(0, 2**31))
    rng = np.random.default_rng(seed)
    # VALID pooling with exact tiling (the model regime; Eq. 13 constant)
    h = k * oh
    x = rng.integers(-128, 128, (1, h, h, c)).astype(np.int8)
    s_x, z_x = qparams(draw)
    s_y, z_y = qparams(draw)
    return x, dict(filter_size=(k, k), stride=(k, k), padding="valid",
                   s_x=s_x, z_x=z_x, s_y=s_y, z_y=z_y)


@given(pool_case())
def test_avgpool_pallas_equals_ref(case):
    x, kw = case
    r = ref.average_pool2d(jnp.asarray(x), **kw)
    p = qk.average_pool2d(jnp.asarray(x), **kw)
    assert_quant_equal(r, p, "avgpool")


@given(st.integers(0, 2**31), st.integers(1, 8), st.integers(2, 10))
def test_softmax_pallas_equals_ref(seed, m, n):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (m, n)).astype(np.int8)
    kw = dict(s_x=0.1, z_x=3, s_y=1 / 256, z_y=-128)
    r = ref.softmax(jnp.asarray(x), **kw)
    p = qk.softmax(jnp.asarray(x), **kw)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(p))


# ---------------------------------------------------------------------------
# targeted regression cases
# ---------------------------------------------------------------------------

def test_qgemm_block_boundary_shapes():
    """Shapes straddling the BlockSpec tiles.

    Allowance: when the float epilogue lands on an exact .5 tie, XLA's FMA
    fusion inside pallas_call can round the other way than the eager
    oracle (observed: y = 59.5 with scale 0.012). Those ties are the only
    permitted deviation: |Δ| <= 1 on < 0.2% of outputs. Everything else is
    bit-exact (the hypothesis sweeps above assert full equality).
    """
    rng = np.random.default_rng(0)
    for m, k, n in [(1, 1, 1), (8, 128, 128), (9, 129, 130), (127, 7, 255)]:
        x = rng.integers(-128, 128, (m, k)).astype(np.int8)
        w = rng.integers(-128, 128, (k, n)).astype(np.int8)
        b = rng.integers(-500, 500, (n,)).astype(np.int32)
        kw = dict(s_x=0.03, z_x=-5, s_w=0.02, z_w=0, s_b=0.0006, z_b=0, s_y=0.05, z_y=4, act="none")
        r = np.asarray(ref.fully_connected(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), **kw))
        p = np.asarray(qk.fully_connected(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), **kw))
        d = np.abs(r.astype(np.int32) - p.astype(np.int32))
        assert d.max() <= 1, f"{m}x{k}x{n}: max diff {d.max()}"
        assert (d > 0).mean() < 0.002, f"{m}x{k}x{n}: {(d > 0).sum()} ties"


def test_extreme_zero_points_saturate_identically():
    rng = np.random.default_rng(1)
    x = rng.integers(-128, 128, (4, 16)).astype(np.int8)
    w = rng.integers(-128, 128, (16, 8)).astype(np.int8)
    b = np.zeros(8, np.int32)
    kw = dict(s_x=0.5, z_x=-128, s_w=0.5, z_w=127, s_b=0.25, z_b=0, s_y=0.001, z_y=0, act="none")
    r = ref.fully_connected(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), **kw)
    p = qk.fully_connected(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), **kw)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(p))


def test_round_half_away_disagrees_with_bankers():
    """Guard: the rounding contract is half-away, not jnp.round (half-even)."""
    v = jnp.asarray([0.5, 1.5, 2.5, -0.5, -2.5], jnp.float32)
    away = np.asarray(ref.round_half_away(v))
    np.testing.assert_array_equal(away, [1.0, 2.0, 3.0, -1.0, -3.0])
    bankers = np.asarray(jnp.round(v))
    assert not np.array_equal(away, bankers)
