"""AOT pipeline: train → quantize → export → lower to HLO text.

This is the single build-time entrypoint (``make artifacts``).  Python never
runs on the request path: after this script finishes, ``artifacts/``
contains everything the Rust binary needs:

    <model>.mfb             — quantized model for the native engines
    <model>_test.mds        — test dataset (Table 5 protocol sizes)
    <model>_golden.bin      — int8 input/output pairs from the jnp oracle
                              (bit-exactness gate for the Rust engine)
    <model>_quant_b<N>.hlo.txt — quantized Pallas inference graph, AOT-lowered
                              to HLO *text* for the Rust PJRT runtime
    <model>_params.npz      — trained float params (training cache)
    manifest.txt            — sizes + metadata (Table 3 regeneration)

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import functools
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets as D
from . import model as M
from . import train as T
from .export_mfb import write_golden, write_mds, write_mfb
from .kernels.ref import quantize as q_input
from .quantize import QuantizedModel, ptq

# batch sizes per model for the AOT'd PJRT executables (one executable per
# variant — the serving coordinator picks the best fit per batch)
AOT_BATCHES = {"sine": (1, 32), "speech": (1, 8), "person": (1,)}
GOLDEN_N = 8


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_quant_model(qm: QuantizedModel, batch: int) -> str:
    """Lower the quantized Pallas forward pass for a fixed batch size."""
    in_shape = (batch, *qm.model.input_shape)

    def fn(x_q):
        return (M.forward_quant(qm, x_q, backend="pallas", interpret=True),)

    spec = jax.ShapeDtypeStruct(in_shape, jnp.int8)
    lowered = jax.jit(fn).lower(spec)
    return to_hlo_text(lowered)


def params_cache_path(art: str, name: str) -> str:
    return os.path.join(art, f"{name}_params.npz")


def save_params(path: str, params: list) -> None:
    flat: dict[str, np.ndarray] = {}
    for i, p in enumerate(params):
        if p is not None:
            flat[f"{i}_w"] = np.asarray(p["w"])
            flat[f"{i}_b"] = np.asarray(p["b"])
    np.savez(path, **flat)


def load_params(path: str, model: M.ModelDef) -> list | None:
    if not os.path.exists(path):
        return None
    z = np.load(path)
    params: list = []
    for i, layer in enumerate(model.layers):
        if f"{i}_w" in z:
            params.append({"w": jnp.asarray(z[f"{i}_w"]), "b": jnp.asarray(z[f"{i}_b"])})
        else:
            params.append(None)
    return params


TEST_SETS = {"sine": D.sine_test, "speech": D.speech_test, "person": D.person_test}
CALIB_SETS = {"sine": D.sine_train, "speech": D.speech_train, "person": D.person_train}


def build_model(name: str, art: str, *, force: bool = False, log=print) -> dict:
    """Run the full pipeline for one model; returns summary facts."""
    model = M.MODELS[name]()
    t0 = time.time()

    params = None if force else load_params(params_cache_path(art, name), model)
    if params is None:
        log(f"[aot] training {name} ...")
        _, params = T.TRAINERS[name](log=log)
        save_params(params_cache_path(art, name), params)
    else:
        log(f"[aot] {name}: using cached params")

    calib = CALIB_SETS[name]()
    calib_x = calib.x[:256]
    qm = ptq(model, params, calib_x)

    mfb_bytes = write_mfb(qm, os.path.join(art, f"{name}.mfb"))
    test = TEST_SETS[name]()
    write_mds(test, os.path.join(art, f"{name}_test.mds"))

    # golden vectors through the *jnp oracle* path (ref backend)
    qin = qm.input_qparams
    gx = q_input(jnp.asarray(test.x[:GOLDEN_N]), qin.scale, qin.zero_point)
    gy = M.forward_quant(qm, gx, backend="ref")
    write_golden(np.asarray(gx), np.asarray(gy), os.path.join(art, f"{name}_golden.bin"))

    # Pallas path must agree bit-exactly with the oracle before we export HLO
    py = M.forward_quant(qm, gx, backend="pallas")
    if not bool(jnp.all(py == gy)):
        raise AssertionError(f"{name}: pallas != ref on golden inputs")

    hlo_sizes = {}
    for b in AOT_BATCHES[name]:
        text = lower_quant_model(qm, b)
        p = os.path.join(art, f"{name}_quant_b{b}.hlo.txt")
        with open(p, "w") as f:
            f.write(text)
        hlo_sizes[b] = len(text)
        log(f"[aot] {name}: wrote {p} ({len(text)} chars)")

    facts = {
        "name": name,
        "params": M.param_count(model),
        "layers": len(model.layers),
        "mfb_bytes": mfb_bytes,
        "weights_bytes": qm.size_bytes(),
        "test_n": test.n,
        "hlo": hlo_sizes,
        "secs": round(time.time() - t0, 1),
    }
    log(f"[aot] {name}: done {facts}")
    return facts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="(legacy) ignored; use --artifacts")
    ap.add_argument("--artifacts", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--models", default="sine,speech,person")
    ap.add_argument("--force", action="store_true", help="retrain even if params are cached")
    args = ap.parse_args()

    art = os.path.abspath(args.artifacts)
    os.makedirs(art, exist_ok=True)
    all_facts = []
    for name in args.models.split(","):
        all_facts.append(build_model(name.strip(), art))

    with open(os.path.join(art, "manifest.txt"), "w") as f:
        f.write("# model | layers | params | weights_bytes | mfb_bytes | test_n\n")
        for fa in all_facts:
            f.write(
                f"{fa['name']} | {fa['layers']} | {fa['params']} | "
                f"{fa['weights_bytes']} | {fa['mfb_bytes']} | {fa['test_n']}\n"
            )
    print("[aot] manifest written; artifacts complete")


if __name__ == "__main__":
    main()
