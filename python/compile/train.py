"""Training for the three paper models (build-time only, DESIGN.md S18).

No optax is available in this environment, so a minimal Adam is hand-rolled
on jax pytrees.  Training is deliberately small-scale: the paper uses
pre-trained TFLM reference models; what our evaluation needs is *trained
quantized models of the same architectures* so the engine-vs-engine
comparison (Table 5) is meaningful.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets as D
from . import model as M

# ---------------------------------------------------------------------------
# minimal Adam
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2**t), v)
    new = jax.tree_util.tree_map(lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def mse_loss(model, params, x, y):
    pred = M.forward_float(model, params, x)
    return jnp.mean((pred - y) ** 2)


def xent_loss(model, params, x, y):
    logits = M.forward_float(model, params, x)  # softmax skipped (logits_only)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


def accuracy(model, params, x, y, batch: int = 256) -> float:
    hits = 0
    for i in range(0, x.shape[0], batch):
        logits = M.forward_float(model, params, jnp.asarray(x[i : i + batch]))
        hits += int(jnp.sum(jnp.argmax(logits, -1) == jnp.asarray(y[i : i + batch])))
    return hits / x.shape[0]


# ---------------------------------------------------------------------------
# generic training loop
# ---------------------------------------------------------------------------


def train(
    model: M.ModelDef,
    train_ds: D.Dataset,
    *,
    steps: int,
    batch: int,
    lr: float,
    seed: int = 0,
    log_every: int = 50,
    log=print,
) -> list:
    """Train ``model`` on ``train_ds``; returns the trained float params."""
    params = M.init_params(model, seed)
    opt = adam_init(params)
    loss_fn = xent_loss if model.classification else mse_loss

    @jax.jit
    def step_fn(params, opt, x, y):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(model, p, x, y))(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    rng = np.random.default_rng(seed)
    n = train_ds.n
    t0 = time.time()
    for s in range(steps):
        idx = rng.integers(0, n, size=batch)
        x = jnp.asarray(train_ds.x[idx])
        y = jnp.asarray(train_ds.y[idx])
        params, opt, loss = step_fn(params, opt, x, y)
        if log_every and (s % log_every == 0 or s == steps - 1):
            log(f"[train:{model.name}] step {s:4d}/{steps} loss={float(loss):.4f} ({time.time()-t0:.1f}s)")
    return params


def train_sine(log=print):
    model = M.sine_model()
    params = train(model, D.sine_train(), steps=3000, batch=64, lr=5e-3, seed=0, log=log)
    return model, params


def train_speech(log=print):
    model = M.speech_model()
    params = train(model, D.speech_train(), steps=500, batch=32, lr=1e-3, seed=1, log=log)
    return model, params


def train_person(log=print):
    model = M.person_model()
    params = train(model, D.person_train(), steps=400, batch=16, lr=1e-3, seed=2, log=log)
    return model, params


TRAINERS = {"sine": train_sine, "speech": train_speech, "person": train_person}
