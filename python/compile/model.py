"""L2: the three paper models as declarative layer graphs (DESIGN.md S18).

A model is a list of layer specs.  One description drives everything:

* ``init_params``   — parameter initialization (training),
* ``forward_float`` — float forward pass (training / PTQ calibration),
* ``forward_quant`` — quantized int8 forward pass calling the **Pallas**
                      kernels (L1); this is the graph that is AOT-lowered to
                      HLO text for the Rust PJRT runtime,
* ``quantize.ptq``  — post-training quantization,
* ``export_mfb``    — serialization to the MFB container for the Rust
                      native engines.

The three models mirror Table 3 of the paper:

* ``sine``   — FC(1→16) ReLU, FC(16→16) ReLU, FC(16→1)
* ``speech`` — TinyConv: DepthwiseConv2D(1→8, 10x8, s2x2) ReLU, Flatten,
               FC(4000→4), Softmax
* ``person`` — MobileNetV1 x0.25 on 96x96x1: Conv + 13 depthwise-separable
               blocks + AvgPool + Conv1x1(→2) + Softmax (30 layers)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import quantized as qk
from .kernels import ref

# ---------------------------------------------------------------------------
# layer spec constructors
# ---------------------------------------------------------------------------


def fc(out_features: int, act: str = "none") -> dict:
    return {"op": "fully_connected", "out": out_features, "act": act}


def conv(filters: int, kernel: tuple[int, int], stride: tuple[int, int], padding: str, act: str = "none") -> dict:
    return {"op": "conv2d", "filters": filters, "kernel": kernel, "stride": stride, "padding": padding, "act": act}


def dwconv(mult: int, kernel: tuple[int, int], stride: tuple[int, int], padding: str, act: str = "none") -> dict:
    return {"op": "depthwise_conv2d", "mult": mult, "kernel": kernel, "stride": stride, "padding": padding, "act": act}


def avgpool(filter_size: tuple[int, int], stride: tuple[int, int], padding: str = "valid") -> dict:
    return {"op": "average_pool2d", "filter": filter_size, "stride": stride, "padding": padding}


def flatten() -> dict:
    return {"op": "reshape", "mode": "flatten"}


def softmax() -> dict:
    return {"op": "softmax"}


@dataclasses.dataclass
class ModelDef:
    """A named model: per-sample input shape (no batch dim) + layer list."""

    name: str
    input_shape: tuple[int, ...]
    layers: list[dict]
    classification: bool


def sine_model() -> ModelDef:
    return ModelDef("sine", (1,), [fc(16, "relu"), fc(16, "relu"), fc(1)], classification=False)


def speech_model() -> ModelDef:
    """TinyConv (paper Fig. 8 centre): dwconv (mult 8) + FC + softmax."""
    return ModelDef(
        "speech",
        (49, 40, 1),
        [
            dwconv(8, (10, 8), (2, 2), "same", "relu"),  # -> 25x20x8
            flatten(),  # -> 4000
            fc(4),
            softmax(),
        ],
        classification=True,
    )


def person_model() -> ModelDef:
    """MobileNetV1 x0.25 (paper Fig. 8 right), 96x96x1 -> 2 classes.

    Channel progression is the standard MobileNet table scaled by 0.25
    (32→8 ... 1024→256); 30 layers counting each op like the paper does.
    """
    layers: list[dict] = [conv(8, (3, 3), (2, 2), "same", "relu6")]  # 96 -> 48
    blocks = [
        (1, 16),  # 48
        (2, 32),  # -> 24
        (1, 32),
        (2, 64),  # -> 12
        (1, 64),
        (2, 128),  # -> 6
        (1, 128),
        (1, 128),
        (1, 128),
        (1, 128),
        (1, 128),
        (2, 256),  # -> 3
        (1, 256),
    ]
    for stride, out_ch in blocks:
        layers.append(dwconv(1, (3, 3), (stride, stride), "same", "relu6"))
        layers.append(conv(out_ch, (1, 1), (1, 1), "same", "relu6"))
    layers += [avgpool((3, 3), (3, 3), "valid"), flatten(), fc(2), softmax()]
    return ModelDef("person", (96, 96, 1), layers, classification=True)


MODELS = {"sine": sine_model, "speech": speech_model, "person": person_model}


# ---------------------------------------------------------------------------
# shape inference
# ---------------------------------------------------------------------------

def layer_shapes(model: ModelDef) -> list[tuple[int, ...]]:
    """Per-sample activation shape after each layer (index 0 = input)."""
    shapes = [model.input_shape]
    s: tuple[int, ...] = model.input_shape
    for layer in model.layers:
        op = layer["op"]
        if op == "fully_connected":
            assert len(s) == 1, f"FC needs flat input, got {s}"
            s = (layer["out"],)
        elif op == "conv2d":
            oh, ow = ref.out_dims(s[0], s[1], *layer["kernel"], *layer["stride"], layer["padding"])
            s = (oh, ow, layer["filters"])
        elif op == "depthwise_conv2d":
            oh, ow = ref.out_dims(s[0], s[1], *layer["kernel"], *layer["stride"], layer["padding"])
            s = (oh, ow, s[2] * layer["mult"])
        elif op == "average_pool2d":
            oh, ow = ref.out_dims(s[0], s[1], *layer["filter"], *layer["stride"], layer["padding"])
            s = (oh, ow, s[2])
        elif op == "reshape":
            s = (int(np.prod(s)),)
        elif op == "softmax":
            pass
        else:
            raise ValueError(op)
        shapes.append(s)
    return shapes


def param_count(model: ModelDef) -> int:
    """Total scalar parameters (weights + biases)."""
    n = 0
    shapes = layer_shapes(model)
    for i, layer in enumerate(model.layers):
        sin = shapes[i]
        op = layer["op"]
        if op == "fully_connected":
            n += sin[0] * layer["out"] + layer["out"]
        elif op == "conv2d":
            kh, kw = layer["kernel"]
            n += layer["filters"] * kh * kw * sin[2] + layer["filters"]
        elif op == "depthwise_conv2d":
            kh, kw = layer["kernel"]
            cout = sin[2] * layer["mult"]
            n += kh * kw * cout + cout
    return n


# ---------------------------------------------------------------------------
# parameters + float forward (training path)
# ---------------------------------------------------------------------------

def init_params(model: ModelDef, seed: int = 0) -> list:
    """He-initialized float parameters; ``None`` for parameterless layers."""
    key = jax.random.PRNGKey(seed)
    shapes = layer_shapes(model)
    params: list = []
    for i, layer in enumerate(model.layers):
        sin = shapes[i]
        op = layer["op"]
        if op == "fully_connected":
            key, k = jax.random.split(key)
            fan_in = sin[0]
            w = jax.random.normal(k, (fan_in, layer["out"]), jnp.float32) * math.sqrt(2.0 / fan_in)
            params.append({"w": w, "b": jnp.zeros((layer["out"],), jnp.float32)})
        elif op == "conv2d":
            key, k = jax.random.split(key)
            kh, kw = layer["kernel"]
            fan_in = kh * kw * sin[2]
            w = jax.random.normal(k, (layer["filters"], kh, kw, sin[2]), jnp.float32) * math.sqrt(2.0 / fan_in)
            params.append({"w": w, "b": jnp.zeros((layer["filters"],), jnp.float32)})
        elif op == "depthwise_conv2d":
            key, k = jax.random.split(key)
            kh, kw = layer["kernel"]
            cout = sin[2] * layer["mult"]
            w = jax.random.normal(k, (1, kh, kw, cout), jnp.float32) * math.sqrt(2.0 / (kh * kw))
            params.append({"w": w, "b": jnp.zeros((cout,), jnp.float32)})
        else:
            params.append(None)
    return params


def _apply_act_float(x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "none":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    raise ValueError(act)


def forward_float(
    model: ModelDef,
    params: list,
    x: jnp.ndarray,
    *,
    collect: bool = False,
    logits_only: bool = True,
) -> Any:
    """Float forward pass.

    ``collect=True`` also returns every intermediate activation (post
    fused-activation) for PTQ calibration.  ``logits_only`` skips the final
    softmax (training uses cross-entropy-with-logits).
    """
    acts = [x]
    for layer, p in zip(model.layers, params):
        op = layer["op"]
        if op == "fully_connected":
            x = ref.fully_connected_float(x, p["w"], p["b"])
            x = _apply_act_float(x, layer["act"])
        elif op == "conv2d":
            x = ref.conv2d_float(x, p["w"], p["b"], layer["stride"], layer["padding"])
            x = _apply_act_float(x, layer["act"])
        elif op == "depthwise_conv2d":
            x = ref.depthwise_conv2d_float(x, p["w"], p["b"], layer["stride"], layer["padding"], layer["mult"])
            x = _apply_act_float(x, layer["act"])
        elif op == "average_pool2d":
            x = ref.average_pool2d_float(x, layer["filter"], layer["stride"], layer["padding"])
        elif op == "reshape":
            x = x.reshape(x.shape[0], -1)
        elif op == "softmax":
            if not logits_only:
                x = jax.nn.softmax(x, axis=-1)
        else:
            raise ValueError(op)
        acts.append(x)
    return (x, acts) if collect else x


# ---------------------------------------------------------------------------
# quantized forward (Pallas path — this is what gets AOT-lowered)
# ---------------------------------------------------------------------------

def forward_quant(
    qmodel,
    x_q: jnp.ndarray,
    *,
    backend: str = "pallas",
    interpret: bool = True,
) -> jnp.ndarray:
    """Quantized int8 forward pass.

    ``backend`` selects the Pallas kernels (``"pallas"``, the L1 hot path)
    or the pure-jnp oracle (``"ref"``); both must agree bit-exactly — the
    equivalence is asserted in python/tests/test_models.py.
    """
    k = qk if backend == "pallas" else ref
    model = qmodel.model
    for layer, lq in zip(model.layers, qmodel.layers):
        op = layer["op"]
        qi, qo = lq["in"], lq["out"]
        common = dict(s_x=qi.scale, z_x=qi.zero_point, s_y=qo.scale, z_y=qo.zero_point)
        extra = {"interpret": interpret} if backend == "pallas" else {}
        if op == "fully_connected":
            x_q = k.fully_connected(
                x_q, jnp.asarray(lq["w_q"]), jnp.asarray(lq["b_q"]),
                s_w=lq["wq"].scale, z_w=lq["wq"].zero_point,
                s_b=lq["bq"].scale, z_b=lq["bq"].zero_point,
                act=layer["act"], **common, **extra,
            )
        elif op == "conv2d":
            x_q = k.conv2d(
                x_q, jnp.asarray(lq["w_q"]), jnp.asarray(lq["b_q"]),
                stride=layer["stride"], padding=layer["padding"],
                s_f=lq["wq"].scale, z_f=lq["wq"].zero_point,
                s_b=lq["bq"].scale, z_b=lq["bq"].zero_point,
                act=layer["act"], **common, **extra,
            )
        elif op == "depthwise_conv2d":
            x_q = k.depthwise_conv2d(
                x_q, jnp.asarray(lq["w_q"]), jnp.asarray(lq["b_q"]),
                stride=layer["stride"], padding=layer["padding"], depth_multiplier=layer["mult"],
                s_w=lq["wq"].scale, z_w=lq["wq"].zero_point,
                s_b=lq["bq"].scale, z_b=lq["bq"].zero_point,
                act=layer["act"], **common, **extra,
            )
        elif op == "average_pool2d":
            x_q = k.average_pool2d(
                x_q, filter_size=layer["filter"], stride=layer["stride"],
                padding=layer["padding"], **common, **extra,
            )
        elif op == "reshape":
            x_q = x_q.reshape(x_q.shape[0], -1)
        elif op == "softmax":
            x_q = k.softmax(x_q, **common, **extra)
        else:
            raise ValueError(op)
    return x_q
