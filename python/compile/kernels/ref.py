"""Pure-jnp oracle for MicroFlow's quantized operators (DESIGN.md S19).

Implements the exact quantized formulas of the paper (Sec. 5 + Appendix A)
with NO Pallas: these are the correctness references the Pallas kernels in
``quantized.py`` and the Rust runtime kernels are validated against.

Arithmetic contract (shared with the Rust MicroFlow engine, see
rust/src/tensor/quant.rs):

* accumulation in int32;
* requantization multiplies the int32 accumulator by a *float32* scale and
  adds a float32 per-output constant (the paper's pre-processed terms,
  Eq. 4/7/10/13), then rounds **half away from zero** and clamps to int8;
* fused activations clamp to [act_min, act_max] in the quantized domain
  (Eq. 15/17).

The TFLM comparator uses gemmlowp fixed-point requantization instead; that
path lives purely in Rust (rust/src/tensor/fixedpoint.rs) and is *expected*
to differ from this oracle by at most one integer unit (paper Sec. 6.2.1).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

INT8_MIN = -128
INT8_MAX = 127


def round_half_away(x: jnp.ndarray) -> jnp.ndarray:
    """Round half away from zero (matches Rust's ``f32::round``).

    ``jnp.round`` rounds half to even, which does NOT match; this must be
    used everywhere a float is converted back to a quantized integer.
    """
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def quantize(r: jnp.ndarray, scale: float, zero_point: int) -> jnp.ndarray:
    """Eq. (1) inverted: q = round(r / S) + Z, clamped to int8."""
    q = round_half_away(r / jnp.float32(scale)) + zero_point
    return jnp.clip(q, INT8_MIN, INT8_MAX).astype(jnp.int8)


def dequantize(q: jnp.ndarray, scale: float, zero_point: int) -> jnp.ndarray:
    """Eq. (1): r = S (q - Z)."""
    return jnp.float32(scale) * (q.astype(jnp.float32) - jnp.float32(zero_point))


def act_bounds(act: str, s_y: float, z_y: int) -> tuple[int, int]:
    """Quantized clamp bounds for a fused activation (Eqs. 15/17).

    Returns (act_min, act_max) in the int8 domain.  ``none`` clamps to the
    full int8 range (saturation only).
    """
    if act == "none":
        return INT8_MIN, INT8_MAX
    if act == "relu":
        return max(INT8_MIN, int(z_y)), INT8_MAX
    if act == "relu6":
        hi = int(np.floor(z_y + 6.0 / s_y + 0.5))
        return max(INT8_MIN, int(z_y)), min(INT8_MAX, hi)
    raise ValueError(f"unknown fused activation {act!r}")


def requantize(
    acc: jnp.ndarray,
    const_bias: jnp.ndarray,
    scale_ratio: float,
    act_min: int,
    act_max: int,
) -> jnp.ndarray:
    """Shared epilogue: y_q = clamp(round(const_bias + scale_ratio * acc)).

    ``const_bias`` is the paper's pre-processed term
    ``z_Y + (s_b/s_Y)(b_q - z_b)`` (float32, broadcast over outputs) and
    ``scale_ratio`` is ``s_X s_W / s_Y`` (float32 scalar).
    """
    y = jnp.float32(const_bias) + jnp.float32(scale_ratio) * acc.astype(jnp.float32)
    return jnp.clip(round_half_away(y), act_min, act_max).astype(jnp.int8)


# ---------------------------------------------------------------------------
# FullyConnected — Eq. (3)
# ---------------------------------------------------------------------------

def fully_connected(
    x_q: jnp.ndarray,  # int8 [M, K]
    w_q: jnp.ndarray,  # int8 [K, N]
    b_q: jnp.ndarray,  # int32 [N]
    *,
    s_x: float,
    z_x: int,
    s_w: float,
    z_w: int,
    s_b: float,
    z_b: int,
    s_y: float,
    z_y: int,
    act: str = "none",
) -> jnp.ndarray:
    """Quantized dense layer, Eq. (3) evaluated literally.

    The four bracketed terms of Eq. (3) are computed separately so the test
    suite can assert the pre-processed/constant split used by both the
    Pallas kernel and the Rust compiler.
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (x_q.shape, w_q.shape)
    xi = x_q.astype(jnp.int32)
    wi = w_q.astype(jnp.int32)
    dot = xi @ wi  # [M, N]
    x_rowsum = jnp.sum(xi, axis=1, keepdims=True)  # [M, 1] — data dependent
    w_colsum = jnp.sum(wi, axis=0, keepdims=True)  # [1, N] — pre-processable
    acc = dot - z_w * x_rowsum - z_x * w_colsum + k * z_x * z_w
    const_bias = jnp.float32(z_y) + (jnp.float32(s_b) / jnp.float32(s_y)) * (
        b_q.astype(jnp.float32) - jnp.float32(z_b)
    )
    scale_ratio = jnp.float32(s_x) * jnp.float32(s_w) / jnp.float32(s_y)
    lo, hi = act_bounds(act, s_y, z_y)
    return requantize(acc, const_bias[None, :], scale_ratio, lo, hi)


# ---------------------------------------------------------------------------
# view extraction — Algorithm 1 (im2col form)
# ---------------------------------------------------------------------------

def out_dims(h: int, w: int, kh: int, kw: int, sh: int, sw: int, padding: str) -> tuple[int, int]:
    """Output spatial dims for SAME/VALID padding (TFLite convention)."""
    if padding == "same":
        return -(-h // sh), -(-w // sw)  # ceil div
    return (h - kh) // sh + 1, (w - kw) // sw + 1


def extract_views(
    x_q: jnp.ndarray,  # int8/int32 [N, H, W, C]
    kh: int,
    kw: int,
    sh: int,
    sw: int,
    padding: str,
    z_x: int,
) -> jnp.ndarray:
    """Algorithm 1: gather the kh*kw receptive field for every output pixel.

    Returns int32 [N, OH, OW, KH, KW, C].  Out-of-bounds positions (SAME
    padding) are filled with ``z_x`` — the quantized representation of real
    zero, so the quantized formulas stay exact (the paper's kernels skip
    padded elements; filling with z_x makes the (X_q - z_X) factor vanish
    identically, which is the same thing).
    """
    n, h, w, c = x_q.shape
    oh, ow = out_dims(h, w, kh, kw, sh, sw, padding)
    if padding == "same":
        # TFLite SAME: total pad = max((o-1)*s + k - in, 0), split low/high
        pad_h = max((oh - 1) * sh + kh - h, 0)
        pad_w = max((ow - 1) * sw + kw - w, 0)
        pt, pl = pad_h // 2, pad_w // 2
        xp = jnp.full((n, h + pad_h, w + pad_w, c), z_x, dtype=jnp.int32)
        xp = xp.at[:, pt : pt + h, pl : pl + w, :].set(x_q.astype(jnp.int32))
    else:
        xp = x_q.astype(jnp.int32)
    idx_h = (jnp.arange(oh) * sh)[:, None] + jnp.arange(kh)[None, :]  # [OH, KH]
    idx_w = (jnp.arange(ow) * sw)[:, None] + jnp.arange(kw)[None, :]  # [OW, KW]
    v = xp[:, idx_h, :, :]  # [N, OH, KH, W', C]
    v = v[:, :, :, idx_w, :]  # [N, OH, KH, OW, KW, C]
    return jnp.transpose(v, (0, 1, 3, 2, 4, 5))  # [N, OH, OW, KH, KW, C]


# ---------------------------------------------------------------------------
# Conv2D — Eq. (6)
# ---------------------------------------------------------------------------

def conv2d(
    x_q: jnp.ndarray,  # int8 [N, H, W, Cin]
    f_q: jnp.ndarray,  # int8 [Cout, KH, KW, Cin]  (TFLite layout)
    b_q: jnp.ndarray,  # int32 [Cout]
    *,
    stride: tuple[int, int],
    padding: str,
    s_x: float,
    z_x: int,
    s_f: float,
    z_f: int,
    s_b: float,
    z_b: int,
    s_y: float,
    z_y: int,
    act: str = "none",
) -> jnp.ndarray:
    """Quantized 2-D convolution, Eq. (6) via view extraction + dot."""
    cout, kh, kw, cin = f_q.shape
    sh, sw = stride
    views = extract_views(x_q, kh, kw, sh, sw, padding, z_x)  # [N,OH,OW,KH,KW,C]
    n, oh, ow = views.shape[:3]
    patches = views.reshape(n * oh * ow, kh * kw * cin)  # int32
    filt = f_q.astype(jnp.int32).reshape(cout, kh * kw * cin).T  # [KKC, Cout]
    dot = patches @ filt
    x_sum = jnp.sum(patches, axis=1, keepdims=True)
    f_sum = jnp.sum(filt, axis=0, keepdims=True)
    kkc = kh * kw * cin
    acc = dot - z_f * x_sum - z_x * f_sum + kkc * z_x * z_f
    const_bias = jnp.float32(z_y) + (jnp.float32(s_b) / jnp.float32(s_y)) * (
        b_q.astype(jnp.float32) - jnp.float32(z_b)
    )
    scale_ratio = jnp.float32(s_x) * jnp.float32(s_f) / jnp.float32(s_y)
    lo, hi = act_bounds(act, s_y, z_y)
    out = requantize(acc, const_bias[None, :], scale_ratio, lo, hi)
    return out.reshape(n, oh, ow, cout)


# ---------------------------------------------------------------------------
# DepthwiseConv2D — Eq. (9)
# ---------------------------------------------------------------------------

def depthwise_conv2d(
    x_q: jnp.ndarray,  # int8 [N, H, W, Cin]
    w_q: jnp.ndarray,  # int8 [1, KH, KW, Cout]  (TFLite layout, Cout = Cin*mult)
    b_q: jnp.ndarray,  # int32 [Cout]
    *,
    stride: tuple[int, int],
    padding: str,
    depth_multiplier: int,
    s_x: float,
    z_x: int,
    s_w: float,
    z_w: int,
    s_b: float,
    z_b: int,
    s_y: float,
    z_y: int,
    act: str = "none",
) -> jnp.ndarray:
    """Quantized depthwise convolution, Eq. (9): channels never merge."""
    _, kh, kw, cout = w_q.shape
    n, h, w, cin = x_q.shape
    assert cout == cin * depth_multiplier, (cout, cin, depth_multiplier)
    sh, sw = stride
    views = extract_views(x_q, kh, kw, sh, sw, padding, z_x)  # [N,OH,OW,KH,KW,Cin]
    oh, ow = views.shape[1:3]
    # replicate each input channel depth_multiplier times -> output channels
    vi = jnp.repeat(views, depth_multiplier, axis=5)  # [N,OH,OW,KH,KW,Cout]
    wi = w_q.astype(jnp.int32)[0]  # [KH, KW, Cout]
    dot = jnp.sum(vi * wi[None, None, None], axis=(3, 4))  # [N,OH,OW,Cout]
    x_sum = jnp.sum(vi, axis=(3, 4))
    w_sum = jnp.sum(wi, axis=(0, 1))  # [Cout]
    mn = kh * kw
    acc = dot - z_w * x_sum - z_x * w_sum[None, None, None, :] + mn * z_x * z_w
    const_bias = jnp.float32(z_y) + (jnp.float32(s_b) / jnp.float32(s_y)) * (
        b_q.astype(jnp.float32) - jnp.float32(z_b)
    )
    scale_ratio = jnp.float32(s_x) * jnp.float32(s_w) / jnp.float32(s_y)
    lo, hi = act_bounds(act, s_y, z_y)
    return requantize(acc, const_bias[None, None, None, :], scale_ratio, lo, hi)


# ---------------------------------------------------------------------------
# AveragePool2D — Eq. (12)
# ---------------------------------------------------------------------------

def average_pool2d(
    x_q: jnp.ndarray,  # int8 [N, H, W, C]
    *,
    filter_size: tuple[int, int],
    stride: tuple[int, int],
    padding: str,
    s_x: float,
    z_x: int,
    s_y: float,
    z_y: int,
    act: str = "none",
) -> jnp.ndarray:
    """Quantized average pooling, Eq. (12).

    VALID padding only sees full windows so the 1/(m n) factor is constant,
    as the paper's pre-processing assumes (Eq. 13).
    """
    kh, kw = filter_size
    sh, sw = stride
    views = extract_views(x_q, kh, kw, sh, sw, padding, z_x)  # [N,OH,OW,KH,KW,C]
    mean = jnp.mean(views.astype(jnp.float32), axis=(3, 4))  # [N,OH,OW,C]
    y = jnp.float32(z_y) + (jnp.float32(s_x) / jnp.float32(s_y)) * (mean - jnp.float32(z_x))
    lo, hi = act_bounds(act, s_y, z_y)
    return jnp.clip(round_half_away(y), lo, hi).astype(jnp.int8)


# ---------------------------------------------------------------------------
# standalone activations — Eqs. (14), (16), (18)
# ---------------------------------------------------------------------------

def relu(x_q: jnp.ndarray, *, s_x: float, z_x: int, s_y: float, z_y: int) -> jnp.ndarray:
    """Eq. (14): standalone (non-fused) quantized ReLU."""
    xf = x_q.astype(jnp.float32)
    y = jnp.where(
        xf < z_x,
        jnp.float32(z_y),
        jnp.float32(z_y) + (jnp.float32(s_x) / jnp.float32(s_y)) * (xf - z_x),
    )
    return jnp.clip(round_half_away(y), INT8_MIN, INT8_MAX).astype(jnp.int8)


def relu6(x_q: jnp.ndarray, *, s_x: float, z_x: int, s_y: float, z_y: int) -> jnp.ndarray:
    """Eq. (16): standalone quantized ReLU6."""
    xf = x_q.astype(jnp.float32)
    knee = jnp.float32(z_x) + 6.0 / jnp.float32(s_x)
    lo = jnp.where(
        xf < z_x,
        jnp.float32(z_y),
        jnp.float32(z_y) + (jnp.float32(s_x) / jnp.float32(s_y)) * (xf - z_x),
    )
    y = jnp.where(xf >= knee, jnp.float32(z_y) + 6.0 / jnp.float32(s_y), lo)
    return jnp.clip(round_half_away(y), INT8_MIN, INT8_MAX).astype(jnp.int8)


def softmax(x_q: jnp.ndarray, *, s_x: float, z_x: int, s_y: float, z_y: int) -> jnp.ndarray:
    """Eq. (18): quantized softmax over the last axis.

    Computed with a max-subtraction for numerical stability; algebraically
    identical to Eq. (18) (the z_x and max terms cancel in the ratio).
    TFLite convention for int8 softmax output is s_y = 1/256, z_y = -128.
    """
    xf = jnp.float32(s_x) * (x_q.astype(jnp.float32) - jnp.float32(z_x))
    xf = xf - jnp.max(xf, axis=-1, keepdims=True)
    e = jnp.exp(xf)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    y = jnp.float32(z_y) + p / jnp.float32(s_y)
    return jnp.clip(round_half_away(y), INT8_MIN, INT8_MAX).astype(jnp.int8)


# ---------------------------------------------------------------------------
# float references (training-time forward passes and PTQ calibration)
# ---------------------------------------------------------------------------

def fully_connected_float(x, w, b):
    """Float dense layer with [K, N] weights (Eq. 2)."""
    return x @ w + b[None, :]


def conv2d_float(x, f, b, stride, padding):
    """Float NHWC conv with TFLite [Cout, KH, KW, Cin] filters (Eq. 5)."""
    import jax

    fw = jnp.transpose(f, (1, 2, 3, 0))  # -> HWIO
    dn = jax.lax.conv_dimension_numbers(x.shape, fw.shape, ("NHWC", "HWIO", "NHWC"))
    out = jax.lax.conv_general_dilated(x, fw, stride, padding.upper(), dimension_numbers=dn)
    return out + b[None, None, None, :]


def depthwise_conv2d_float(x, w, b, stride, padding, depth_multiplier):
    """Float depthwise conv with TFLite [1, KH, KW, Cout] filters (Eq. 8)."""
    import jax

    cin = x.shape[3]
    kh, kw, cout = w.shape[1], w.shape[2], w.shape[3]
    assert cout == cin * depth_multiplier
    fw = w[0].reshape(kh, kw, cin, depth_multiplier)
    fw = fw.reshape(kh, kw, 1, cout)
    dn = jax.lax.conv_dimension_numbers(x.shape, fw.shape, ("NHWC", "HWIO", "NHWC"))
    out = jax.lax.conv_general_dilated(
        x, fw, stride, padding.upper(), dimension_numbers=dn, feature_group_count=cin
    )
    return out + b[None, None, None, :]


def average_pool2d_float(x, filter_size, stride, padding):
    """Float average pooling (Eq. 11)."""
    import jax

    kh, kw = filter_size
    out = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, kh, kw, 1), (1, stride[0], stride[1], 1), padding.upper()
    )
    return out / float(kh * kw)
