"""Pallas kernels for MicroFlow's quantized operator hot-spots (L1).

The paper's hot path is the int8 multiply-accumulate inner loop of
FullyConnected / Conv2D / DepthwiseConv2D (Sec. 5).  On TPU the same insight
maps onto the MXU (DESIGN.md §5 Hardware adaptation):

* everything input-independent (Eq. 4/7/10) is folded *outside* the kernel —
  the per-output-column int32 constants and the float32 requant scale are
  kernel operands, exactly mirroring the Rust compiler's ``preprocess`` step;
* the kernel body is a pure int8→int32 matmul (MXU-shaped) plus a
  vectorized float epilogue (VPU);
* the paper's *paging* (Sec. 4.3) is expressed as the BlockSpec grid over
  output columns: one page == one grid step over N.

All kernels run with ``interpret=True`` (the CPU PJRT plugin cannot execute
Mosaic custom-calls); correctness is asserted against ``ref.py`` bit-exactly
in python/tests/.  Real-TPU VMEM/MXU estimates are documented in
EXPERIMENTS.md §Perf.

Bit-exactness contract: identical int32 accumulation and the identical
float32 epilogue ``round_half_away(const_bias[j] + scale * acc)`` as ref.py
and the Rust engine.
"""

from __future__ import annotations

import functools

import jax
import numpy as np
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

INT8_MIN = -128
INT8_MAX = 127

# Default MXU-aligned tile sizes. On a real TPU these map to the systolic
# array (128x128) and the 8-sublane VPU registers; under interpret=True they
# only affect how the grid is carved. Chosen by the L1 perf pass (see
# EXPERIMENTS.md §Perf: block-shape sweep).
BLOCK_M = 128
BLOCK_N = 128


def _round_half_away(x):
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def _pad_to(a: jnp.ndarray, axis: int, mult: int, value) -> jnp.ndarray:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


# ---------------------------------------------------------------------------
# quantized GEMM — the shared hot-spot of FullyConnected and Conv2D(im2col)
# ---------------------------------------------------------------------------

def _qgemm_kernel(
    x_ref,  # int8 [bm, K]
    w_ref,  # int8 [K, bn]
    wsum_ref,  # int32 [1, bn]   pre-processed  z_x * sum_k W
    cbias_ref,  # f32 [1, bn]    pre-processed  z_y + s_b/s_y (b - z_b)
    o_ref,  # int8 [bm, bn]
    *,
    k: int,
    z_x: int,
    z_w: int,
    scale_ratio: float,
    act_min: int,
    act_max: int,
):
    """One (bm, bn) output tile of Eq. (3).

    int32 MXU matmul + data-dependent row-sum correction, then the float32
    VPU epilogue. ``k * z_x * z_w`` is a compile-time constant.
    """
    xi = x_ref[...].astype(jnp.int32)
    wi = w_ref[...].astype(jnp.int32)
    dot = jax.lax.dot_general(
        xi, wi, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    rowsum = jnp.sum(xi, axis=1, keepdims=True)  # [bm, 1]
    acc = dot - z_w * rowsum - wsum_ref[...] + jnp.int32(k * z_x * z_w)
    y = cbias_ref[...] + jnp.float32(scale_ratio) * acc.astype(jnp.float32)
    yq = jnp.clip(_round_half_away(y), act_min, act_max)
    o_ref[...] = yq.astype(jnp.int8)


def qgemm(
    x_q: jnp.ndarray,  # int8 [M, K]
    w_q: jnp.ndarray,  # int8 [K, N]
    b_q: jnp.ndarray,  # int32 [N]
    *,
    s_x: float,
    z_x: int,
    s_w: float,
    z_w: int,
    s_b: float,
    z_b: int,
    s_y: float,
    z_y: int,
    act: str = "none",
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    interpret: bool = True,
) -> jnp.ndarray:
    """Quantized GEMM with the Eq. (3) epilogue, tiled over (M, N).

    Padding strategy keeps the quantized algebra exact: rows of ``x`` are
    padded with ``z_x`` and columns of ``w`` with ``z_w`` so every padded
    contribution of (X-z_x)(W-z_w) vanishes; padded outputs are sliced off.
    """
    m, k = x_q.shape
    _, n = w_q.shape
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))

    xp = _pad_to(x_q, 0, bm, z_x)
    wp = _pad_to(w_q, 1, bn, z_w)
    mp, np_ = xp.shape[0], wp.shape[1]

    # pre-processed constants (the MicroFlow Compiler side of the split)
    wsum = z_x * jnp.sum(wp.astype(jnp.int32), axis=0, keepdims=True)  # [1, Np]
    cbias = jnp.float32(z_y) + (jnp.float32(s_b) / jnp.float32(s_y)) * (
        b_q.astype(jnp.float32) - jnp.float32(z_b)
    )
    cbias = _pad_to(cbias[None, :], 1, bn, 0.0)
    scale_ratio = float(np.float32(s_x) * np.float32(s_w) / np.float32(s_y))
    act_min, act_max = ref.act_bounds(act, s_y, z_y)

    kernel = functools.partial(
        _qgemm_kernel,
        k=k,
        z_x=z_x,
        z_w=z_w,
        scale_ratio=scale_ratio,
        act_min=act_min,
        act_max=act_max,
    )
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int8),
        interpret=interpret,
    )(xp, wp, wsum, cbias)
    return out[:m, :n]


def fully_connected(x_q, w_q, b_q, **kw) -> jnp.ndarray:
    """FullyConnected (Eq. 3) == qgemm on [M, K] x [K, N]."""
    return qgemm(x_q, w_q, b_q, **kw)


# ---------------------------------------------------------------------------
# Conv2D — Eq. (6) as im2col + qgemm
# ---------------------------------------------------------------------------

def conv2d(
    x_q: jnp.ndarray,  # int8 [N, H, W, Cin]
    f_q: jnp.ndarray,  # int8 [Cout, KH, KW, Cin]
    b_q: jnp.ndarray,  # int32 [Cout]
    *,
    stride: tuple[int, int],
    padding: str,
    s_x: float,
    z_x: int,
    s_f: float,
    z_f: int,
    s_b: float,
    z_b: int,
    s_y: float,
    z_y: int,
    act: str = "none",
    interpret: bool = True,
) -> jnp.ndarray:
    """Quantized Conv2D: Algorithm-1 view extraction (L2) + qgemm (L1).

    On MCU the paper fuses view extraction into the kernel loop; on TPU the
    idiomatic mapping is im2col (a relayout the XLA fusion absorbs) feeding
    the MXU GEMM. Padded positions carry z_x so the algebra is unchanged.
    """
    cout, kh, kw, cin = f_q.shape
    views = ref.extract_views(x_q, kh, kw, stride[0], stride[1], padding, z_x)
    n, oh, ow = views.shape[:3]
    patches = views.reshape(n * oh * ow, kh * kw * cin).astype(jnp.int8)
    filt = f_q.reshape(cout, kh * kw * cin).T  # [KKC, Cout], int8
    out = qgemm(
        patches, filt, b_q,
        s_x=s_x, z_x=z_x, s_w=s_f, z_w=z_f, s_b=s_b, z_b=z_b,
        s_y=s_y, z_y=z_y, act=act, interpret=interpret,
    )
    return out.reshape(n, oh, ow, cout)


# ---------------------------------------------------------------------------
# DepthwiseConv2D — Eq. (9)
# ---------------------------------------------------------------------------

def _qdepthwise_kernel(
    v_ref,  # int8 [bb, KK, C]   extracted views (replicated to Cout)
    w_ref,  # int8 [KK, C]
    wsum_ref,  # int32 [1, C]    z_x * sum W
    cbias_ref,  # f32 [1, C]
    o_ref,  # int8 [bb, C]
    *,
    mn: int,
    z_x: int,
    z_w: int,
    scale_ratio: float,
    act_min: int,
    act_max: int,
):
    """One block of output pixels for all channels (Eq. 9 epilogue)."""
    vi = v_ref[...].astype(jnp.int32)  # [bb, KK, C]
    wi = w_ref[...].astype(jnp.int32)  # [KK, C]
    dot = jnp.sum(vi * wi[None], axis=1)  # [bb, C]
    xsum = jnp.sum(vi, axis=1)  # [bb, C]
    acc = dot - z_w * xsum - wsum_ref[...] + jnp.int32(mn * z_x * z_w)
    y = cbias_ref[...] + jnp.float32(scale_ratio) * acc.astype(jnp.float32)
    o_ref[...] = jnp.clip(_round_half_away(y), act_min, act_max).astype(jnp.int8)


def depthwise_conv2d(
    x_q: jnp.ndarray,  # int8 [N, H, W, Cin]
    w_q: jnp.ndarray,  # int8 [1, KH, KW, Cout]
    b_q: jnp.ndarray,  # int32 [Cout]
    *,
    stride: tuple[int, int],
    padding: str,
    depth_multiplier: int,
    s_x: float,
    z_x: int,
    s_w: float,
    z_w: int,
    s_b: float,
    z_b: int,
    s_y: float,
    z_y: int,
    act: str = "none",
    block_b: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Quantized DepthwiseConv2D (Eq. 9) as a Pallas per-channel MAC kernel."""
    _, kh, kw, cout = w_q.shape
    n, h, w, cin = x_q.shape
    assert cout == cin * depth_multiplier
    views = ref.extract_views(x_q, kh, kw, stride[0], stride[1], padding, z_x)
    oh, ow = views.shape[1:3]
    vi = jnp.repeat(views, depth_multiplier, axis=5)  # [N,OH,OW,KH,KW,Cout]
    bpix = n * oh * ow
    v = vi.reshape(bpix, kh * kw, cout).astype(jnp.int8)

    bb = min(block_b, max(8, bpix))
    vp = _pad_to(v, 0, bb, z_x)
    wk = w_q[0].reshape(kh * kw, cout)

    wsum = z_x * jnp.sum(wk.astype(jnp.int32), axis=0, keepdims=True)
    cbias = jnp.float32(z_y) + (jnp.float32(s_b) / jnp.float32(s_y)) * (
        b_q.astype(jnp.float32) - jnp.float32(z_b)
    )
    scale_ratio = float(np.float32(s_x) * np.float32(s_w) / np.float32(s_y))
    act_min, act_max = ref.act_bounds(act, s_y, z_y)

    kernel = functools.partial(
        _qdepthwise_kernel,
        mn=kh * kw,
        z_x=z_x,
        z_w=z_w,
        scale_ratio=scale_ratio,
        act_min=act_min,
        act_max=act_max,
    )
    out = pl.pallas_call(
        kernel,
        grid=(vp.shape[0] // bb,),
        in_specs=[
            pl.BlockSpec((bb, kh * kw, cout), lambda i: (i, 0, 0)),
            pl.BlockSpec((kh * kw, cout), lambda i: (0, 0)),
            pl.BlockSpec((1, cout), lambda i: (0, 0)),
            pl.BlockSpec((1, cout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((vp.shape[0], cout), jnp.int8),
        interpret=interpret,
    )(vp, wk, wsum, cbias[None, :])
    return out[:bpix].reshape(n, oh, ow, cout)


# ---------------------------------------------------------------------------
# AveragePool2D — Eq. (12)
# ---------------------------------------------------------------------------

def _qavgpool_kernel(
    v_ref,  # int8 [bb, KK, C]
    o_ref,  # int8 [bb, C]
    *,
    mn: int,
    z_x: int,
    scale_ratio: float,
    z_y: int,
    act_min: int,
    act_max: int,
):
    vi = v_ref[...].astype(jnp.float32)
    mean = jnp.sum(vi, axis=1) / jnp.float32(mn)
    y = jnp.float32(z_y) + jnp.float32(scale_ratio) * (mean - jnp.float32(z_x))
    o_ref[...] = jnp.clip(_round_half_away(y), act_min, act_max).astype(jnp.int8)


def average_pool2d(
    x_q: jnp.ndarray,
    *,
    filter_size: tuple[int, int],
    stride: tuple[int, int],
    padding: str,
    s_x: float,
    z_x: int,
    s_y: float,
    z_y: int,
    act: str = "none",
    block_b: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Quantized AveragePool2D (Eq. 12) as a Pallas reduction kernel."""
    kh, kw = filter_size
    n, h, w, c = x_q.shape
    views = ref.extract_views(x_q, kh, kw, stride[0], stride[1], padding, z_x)
    oh, ow = views.shape[1:3]
    bpix = n * oh * ow
    v = views.reshape(bpix, kh * kw, c).astype(jnp.int8)
    bb = min(block_b, max(8, bpix))
    vp = _pad_to(v, 0, bb, z_x)
    scale_ratio = float(np.float32(s_x) / np.float32(s_y))
    act_min, act_max = ref.act_bounds(act, s_y, z_y)
    kernel = functools.partial(
        _qavgpool_kernel,
        mn=kh * kw, z_x=z_x, scale_ratio=scale_ratio, z_y=z_y,
        act_min=act_min, act_max=act_max,
    )
    out = pl.pallas_call(
        kernel,
        grid=(vp.shape[0] // bb,),
        in_specs=[pl.BlockSpec((bb, kh * kw, c), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bb, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((vp.shape[0], c), jnp.int8),
        interpret=interpret,
    )(vp)
    return out[:bpix].reshape(n, oh, ow, c)


# ---------------------------------------------------------------------------
# Softmax — Eq. (18)
# ---------------------------------------------------------------------------

def _qsoftmax_kernel(x_ref, o_ref, *, s_x: float, z_x: int, s_y: float, z_y: int):
    xf = jnp.float32(s_x) * (x_ref[...].astype(jnp.float32) - jnp.float32(z_x))
    xf = xf - jnp.max(xf, axis=-1, keepdims=True)
    e = jnp.exp(xf)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    y = jnp.float32(z_y) + p / jnp.float32(s_y)
    o_ref[...] = jnp.clip(_round_half_away(y), INT8_MIN, INT8_MAX).astype(jnp.int8)


def softmax(
    x_q: jnp.ndarray,  # int8 [M, N]
    *,
    s_x: float,
    z_x: int,
    s_y: float,
    z_y: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Quantized softmax (Eq. 18) as a single-block Pallas kernel."""
    kernel = functools.partial(_qsoftmax_kernel, s_x=s_x, z_x=z_x, s_y=s_y, z_y=z_y)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x_q.shape, jnp.int8),
        interpret=interpret,
    )(x_q)
