"""Synthetic datasets for the three paper models (DESIGN.md S17).

The paper evaluates on (a) a custom noisy sine wave, (b) Speech Commands v2,
and (c) Visual Wake Words.  The latter two are not available in this
environment, so we generate synthetic datasets with the *same tensor shapes
and class structure* (see DESIGN.md §4 Substitutions):

* ``sine``    — x in [0, 2*pi], target sin(x); eval targets carry uniform
                noise U(-0.1, 0.1) exactly as in Sec. 6.2.1.
* ``speech``  — 4-class (yes / no / silence / unknown) synthetic 49x40x1
                "spectrograms": each class is a distinct time-frequency
                energy pattern plus noise, so a TinyConv can learn it but
                not trivially (paper-level accuracy ~90% is the target
                regime, not 100%).
* ``person``  — 2-class (person / not-person) synthetic 96x96x1 grayscale
                images: "person" frames contain a vertically-elongated
                bright blob with a head-like disc; negatives contain
                horizontal structures, texture, or nothing.

Everything is deterministic given the seed.  Test-set sizes follow the
paper: 1000 (sine), 1236 (speech), 406 (person).
"""

from __future__ import annotations

import dataclasses

import numpy as np

SINE_TEST_N = 1000
SPEECH_TEST_N = 1236
PERSON_TEST_N = 406

SPEECH_SHAPE = (49, 40, 1)
PERSON_SHAPE = (96, 96, 1)

SPEECH_CLASSES = ("silence", "unknown", "yes", "no")
PERSON_CLASSES = ("not-person", "person")


@dataclasses.dataclass
class Dataset:
    """A dataset split: features ``x`` (float32) and labels ``y``.

    ``y`` is float32 of shape (n, d) for regression and int32 of shape (n,)
    for classification.
    """

    name: str
    x: np.ndarray
    y: np.ndarray

    @property
    def is_classification(self) -> bool:
        return self.y.dtype == np.int32

    @property
    def n(self) -> int:
        return self.x.shape[0]


# ---------------------------------------------------------------------------
# sine predictor
# ---------------------------------------------------------------------------

def sine_train(n: int = 4000, seed: int = 0) -> Dataset:
    """Clean sine regression data used to train the FC-16-16-1 predictor."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.0, 2.0 * np.pi, size=(n, 1)).astype(np.float32)
    y = np.sin(x).astype(np.float32)
    return Dataset("sine-train", x, y)


def sine_test(n: int = SINE_TEST_N, seed: int = 1) -> Dataset:
    """Paper Sec. 6.2.1: 1000 samples of sin(x) + U(-0.1, 0.1) noise.

    Targets carry the noise; MSE is computed against the *actual* function
    values by the harness, matching the paper's protocol.
    """
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.0, 2.0 * np.pi, size=(n, 1)).astype(np.float32)
    noise = rng.uniform(-0.1, 0.1, size=(n, 1)).astype(np.float32)
    y = (np.sin(x) + noise).astype(np.float32)
    return Dataset("sine-test", x, y)


# ---------------------------------------------------------------------------
# speech command recognizer (synthetic 4-class spectrograms)
# ---------------------------------------------------------------------------

def _speech_sample(rng: np.random.Generator, label: int) -> np.ndarray:
    """One synthetic 49x40 "spectrogram" for the given class.

    Class templates (time on axis 0, frequency on axis 1):
      0 silence — low-amplitude noise floor only.
      1 unknown — random broadband bursts at random times.
      2 yes     — rising chirp: energy band sweeping low->high frequency.
      3 no      — falling chirp: energy band sweeping high->low frequency.
    """
    t, f = SPEECH_SHAPE[0], SPEECH_SHAPE[1]
    img = rng.normal(0.0, 0.22, size=(t, f)).astype(np.float32)
    amp = rng.uniform(0.12, 0.75)  # down to near the noise floor -> hard cases
    if label == 0:  # silence: floor, but occasionally a faint blip (confusable)
        if rng.random() < 0.25:
            t0 = rng.integers(0, t - 4)
            img[t0 : t0 + 3, :] += 0.15 * rng.random(f)
    elif label == 1:  # unknown: bursts, or a short ambiguous chirp fragment
        if rng.random() < 0.35:
            rising = rng.random() < 0.5
            start = rng.integers(5, 25)
            span = rng.integers(6, 14)  # too short to be a clear yes/no
            _add_chirp(img, rng, rising, start, span, amp)
        else:
            for _ in range(rng.integers(1, 4)):
                t0 = rng.integers(0, t - 6)
                img[t0 : t0 + 6, :] += amp * rng.uniform(0.4, 1.0) * rng.random(f)
    else:
        # chirp direction encodes yes (rising) vs no (falling)
        rising = label == 2
        start = rng.integers(2, 12)
        span = rng.integers(20, t - start)
        _add_chirp(img, rng, rising, start, span, amp)
    return img.reshape(SPEECH_SHAPE)


def _add_chirp(img: np.ndarray, rng: np.random.Generator, rising: bool, start: int, span: int, amp: float) -> None:
    t, f = img.shape
    width = rng.uniform(3.0, 6.0)
    ts = np.arange(t, dtype=np.float32)
    prog = np.clip((ts - start) / span, 0.0, 1.0)
    center = prog * (f - 8) + 4 if rising else (1.0 - prog) * (f - 8) + 4
    fs = np.arange(f, dtype=np.float32)
    band = np.exp(-0.5 * ((fs[None, :] - center[:, None]) / width) ** 2)
    active = ((ts >= start) & (ts <= start + span)).astype(np.float32)
    img += amp * band * active[:, None]


def speech_split(n: int, seed: int, name: str) -> Dataset:
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 4, size=n).astype(np.int32)
    x = np.stack([_speech_sample(rng, int(l)) for l in labels])
    return Dataset(name, x.astype(np.float32), labels)


def speech_train(n: int = 3000, seed: int = 10) -> Dataset:
    return speech_split(n, seed, "speech-train")


def speech_test(n: int = SPEECH_TEST_N, seed: int = 11) -> Dataset:
    return speech_split(n, seed, "speech-test")


# ---------------------------------------------------------------------------
# person detector (synthetic 2-class 96x96 grayscale)
# ---------------------------------------------------------------------------

def _blob(img: np.ndarray, cy: float, cx: float, ry: float, rx: float, amp: float) -> None:
    h, w = img.shape
    ys = np.arange(h, dtype=np.float32)[:, None]
    xs = np.arange(w, dtype=np.float32)[None, :]
    img += amp * np.exp(-(((ys - cy) / ry) ** 2 + ((xs - cx) / rx) ** 2))


def _person_sample(rng: np.random.Generator, label: int) -> np.ndarray:
    """Deliberately confusable: negatives include head-less torsos and
    detached head+bar compositions; positives can be faint, occluded or
    partially out of frame — targeting the paper's ~78% F1 regime rather
    than a saturated classifier."""
    h, w = PERSON_SHAPE[0], PERSON_SHAPE[1]
    img = rng.normal(0.35, 0.14, size=(h, w)).astype(np.float32)
    # background clutter for both classes
    for _ in range(rng.integers(1, 5)):
        _blob(img, rng.uniform(0, h), rng.uniform(0, w), rng.uniform(3, 10), rng.uniform(3, 10), rng.uniform(-0.25, 0.25))
    if label == 1:
        # "person": vertically elongated torso + head disc above it
        cx = rng.uniform(14, w - 14)
        cy = rng.uniform(40, 78)
        scale = rng.uniform(0.55, 1.3)
        amp = rng.uniform(0.13, 0.42)  # can sink near the clutter level
        _blob(img, cy, cx, 18 * scale, 7 * scale, amp)  # torso
        head_dx = rng.uniform(-4, 4) * scale  # slight head offset
        _blob(img, cy - 24 * scale, cx + head_dx, 6 * scale, 5.5 * scale, amp * rng.uniform(0.7, 1.1))
        if rng.random() < 0.45:  # occlusion bar across the figure
            y0 = int(rng.uniform(cy - 18 * scale, cy + 8 * scale))
            img[max(0, y0) : max(0, y0) + rng.integers(3, 7), :] = rng.uniform(0.3, 0.5)
    else:
        # "not-person": structures sharing parts with the person template
        kind = rng.integers(0, 4)
        amp = rng.uniform(0.2, 0.6)
        if kind == 0:  # head-less torso (vertical blob, no head)
            _blob(img, rng.uniform(40, 78), rng.uniform(14, w - 14), rng.uniform(10, 22), rng.uniform(5, 9), amp)
        elif kind == 1:  # detached "head" far from any torso + horizontal bar
            _blob(img, rng.uniform(10, 40), rng.uniform(10, w - 10), rng.uniform(4, 8), rng.uniform(4, 8), amp)
            y0 = rng.integers(50, h - 10)
            img[y0 : y0 + rng.integers(4, 9), :] += rng.uniform(0.2, 0.45)
        elif kind == 2:  # wide horizontal blob
            _blob(img, rng.uniform(20, h - 20), rng.uniform(20, w - 20), rng.uniform(5, 9), rng.uniform(18, 30), amp)
        # kind == 3: clutter only
    return np.clip(img, 0.0, 1.0).reshape(PERSON_SHAPE)


def person_split(n: int, seed: int, name: str) -> Dataset:
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 2, size=n).astype(np.int32)
    x = np.stack([_person_sample(rng, int(l)) for l in labels])
    return Dataset(name, x.astype(np.float32), labels)


def person_train(n: int = 1600, seed: int = 20) -> Dataset:
    return person_split(n, seed, "person-train")


def person_test(n: int = PERSON_TEST_N, seed: int = 21) -> Dataset:
    return person_split(n, seed, "person-test")
