"""Post-training quantization (PTQ) to int8 — DESIGN.md S18.

Follows the TFLite full-integer scheme the paper builds on (Eq. 1 and
[26] Jacob et al.):

* activations — per-tensor **asymmetric** int8: scale/zero-point from the
  observed min/max over a calibration set (forced to contain real 0);
* weights     — per-tensor **symmetric** int8 (z_W = 0), scale = max|w|/127.
  The paper's equations keep z_W general, and so do all our kernels; our
  exported models simply have z_W = 0 like TFLite's;
* biases      — int32 with s_b = s_X * s_W, z_b = 0;
* softmax output — fixed s = 1/256, z = -128 (TFLite convention).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from . import model as M

INT8_MIN = -128
INT8_MAX = 127


@dataclasses.dataclass(frozen=True)
class QParams:
    """Affine quantization parameters of Eq. (1): r = scale * (q - zero_point)."""

    scale: float
    zero_point: int

    def quantize(self, r: np.ndarray) -> np.ndarray:
        return quantize_array(r, self)

    def dequantize(self, q: np.ndarray) -> np.ndarray:
        return np.float32(self.scale) * (q.astype(np.float32) - np.float32(self.zero_point))


def quantize_array(r: np.ndarray, qp: QParams) -> np.ndarray:
    """q = clamp(round_half_away(r / S) + Z) — matches ref.quantize."""
    x = r / np.float32(qp.scale)
    q = np.sign(x) * np.floor(np.abs(x) + 0.5) + qp.zero_point
    return np.clip(q, INT8_MIN, INT8_MAX).astype(np.int8)


def activation_qparams(lo: float, hi: float) -> QParams:
    """Asymmetric int8 params covering [lo, hi] (always including 0)."""
    lo, hi = float(min(lo, 0.0)), float(max(hi, 0.0))
    if hi - lo < 1e-8:
        hi = lo + 1e-8
    scale = (hi - lo) / 255.0
    zp = int(round(INT8_MIN - lo / scale))
    return QParams(float(np.float32(scale)), int(np.clip(zp, INT8_MIN, INT8_MAX)))


def weight_qparams(w: np.ndarray) -> QParams:
    """Symmetric int8 params (z = 0)."""
    m = float(np.max(np.abs(w)))
    if m < 1e-8:
        m = 1e-8
    return QParams(float(np.float32(m / 127.0)), 0)


SOFTMAX_OUT = QParams(1.0 / 256.0, -128)


@dataclasses.dataclass
class QuantizedModel:
    """A fully quantized model ready for export and inference.

    ``layers[i]`` holds, for layer i of ``model.layers``:
      in / out : activation QParams (out == post-fused-activation range)
      wq / bq  : weight / bias QParams  (None for parameterless layers)
      w_q / b_q: quantized arrays       (None for parameterless layers)
    """

    model: M.ModelDef
    layers: list[dict]

    @property
    def input_qparams(self) -> QParams:
        return self.layers[0]["in"]

    @property
    def output_qparams(self) -> QParams:
        return self.layers[-1]["out"]

    def size_bytes(self) -> int:
        """int8 weights + int32 biases (the paper's 'Size' column)."""
        n = 0
        for lq in self.layers:
            if lq.get("w_q") is not None:
                n += lq["w_q"].size + 4 * lq["b_q"].size
        return n


def ptq(
    model: M.ModelDef,
    params: list,
    calib_x: np.ndarray,
    *,
    smooth_pct: float = 0.0,
) -> QuantizedModel:
    """Calibrate activation ranges on ``calib_x`` and quantize everything.

    ``smooth_pct`` optionally clips the observed range to the given
    percentile (0 = plain min/max, the TFLite default for small models).
    """
    _, acts = M.forward_float(model, params, jnp.asarray(calib_x), collect=True, logits_only=True)
    acts = [np.asarray(a) for a in acts]

    def arange(a: np.ndarray) -> QParams:
        if smooth_pct > 0.0:
            lo = float(np.percentile(a, smooth_pct))
            hi = float(np.percentile(a, 100.0 - smooth_pct))
        else:
            lo, hi = float(a.min()), float(a.max())
        return activation_qparams(lo, hi)

    qlayers: list[dict] = []
    for i, (layer, p) in enumerate(zip(model.layers, params)):
        op = layer["op"]
        qin = arange(acts[i])
        if op == "softmax":
            qout = SOFTMAX_OUT
        elif op == "reshape":
            qout = qin  # reshape never requantizes (paper Table 2)
        else:
            qout = arange(acts[i + 1])
        lq: dict = {"in": qin, "out": qout, "wq": None, "bq": None, "w_q": None, "b_q": None}
        if p is not None:
            w = np.asarray(p["w"], np.float32)
            b = np.asarray(p["b"], np.float32)
            wq = weight_qparams(w)
            sb = float(np.float32(qin.scale) * np.float32(wq.scale))
            bq = QParams(sb, 0)
            lq["wq"] = wq
            lq["bq"] = bq
            lq["w_q"] = quantize_array(w, wq)
            x = b / np.float32(sb)
            lq["b_q"] = (np.sign(x) * np.floor(np.abs(x) + 0.5)).astype(np.int64).clip(-(2**31), 2**31 - 1).astype(np.int32)
        qlayers.append(lq)

    # stitch: layer i's out MUST equal layer i+1's in (single-path graphs)
    for i in range(len(qlayers) - 1):
        qlayers[i + 1]["in"] = qlayers[i]["out"]
        # bias scale depends on the (possibly stitched) input scale — redo it
        if qlayers[i + 1]["w_q"] is not None:
            layer_p = params[i + 1]
            qin = qlayers[i + 1]["in"]
            wq = qlayers[i + 1]["wq"]
            sb = float(np.float32(qin.scale) * np.float32(wq.scale))
            qlayers[i + 1]["bq"] = QParams(sb, 0)
            b = np.asarray(layer_p["b"], np.float32)
            x = b / np.float32(sb)
            qlayers[i + 1]["b_q"] = (
                (np.sign(x) * np.floor(np.abs(x) + 0.5)).astype(np.int64).clip(-(2**31), 2**31 - 1).astype(np.int32)
            )

    return QuantizedModel(model, qlayers)
