"""Binary exporters: MFB models, MDS datasets, GLD golden vectors.

These are the build-time halves of the three containers parsed by the Rust
side (rust/src/format/).  Byte layouts are mirrored there; any change must
be made in both places and bump the version field.

MFB ("MicroFlow Binary", .mfb) — semantic equivalent of the paper's TFLite
FlatBuffers input (DESIGN.md §4 Substitutions).  Little-endian:

    magic "MFB1" | u32 version=1 | str producer
    u32 n_tensors | tensor*
    u32 n_ops     | op*
    u8 n_graph_in  | i32*   (tensor indices)
    u8 n_graph_out | i32*
    str metadata

    str    := u16 len | utf8 bytes
    tensor := str name | u8 dtype(0=i8,1=i32,2=f32) | u8 ndims | u32* dims
              | f32 scale | i32 zero_point | u64 nbytes | bytes data
    op     := u8 opcode | u32 version | u8 n_in | i32* | u8 n_out | i32*
              | u16 opt_len | opts

    opcodes: 0 FullyConnected | 1 Conv2D | 2 DepthwiseConv2D
             | 3 AveragePool2D | 4 Reshape | 5 Softmax | 6 Relu | 7 Relu6
    opts:
      FullyConnected  : u8 fused_act (0 none, 1 relu, 2 relu6)
      Conv2D          : u8 stride_h | u8 stride_w | u8 padding(0 same,1 valid) | u8 fused_act
      DepthwiseConv2D : as Conv2D | u32 depth_multiplier
      AveragePool2D   : u8 filter_h | u8 filter_w | u8 stride_h | u8 stride_w | u8 padding | u8 fused_act
      Reshape         : u8 ndims | u32* dims   (per-sample target shape)
      Softmax         : f32 beta
      Relu/Relu6      : (empty)

Activation tensors have nbytes=0 (no data); weights/biases carry payloads.
Names, versions and metadata are retained on purpose: the interpreter
baseline must parse them at runtime like TFLM parses the FlatBuffer, while
the MicroFlow compiler strips them (paper Sec. 6.2.2).
"""

from __future__ import annotations

import struct

import numpy as np

from . import datasets as D
from .model import ModelDef, layer_shapes
from .quantize import QuantizedModel

OPCODES = {
    "fully_connected": 0,
    "conv2d": 1,
    "depthwise_conv2d": 2,
    "average_pool2d": 3,
    "reshape": 4,
    "softmax": 5,
    "relu": 6,
    "relu6": 7,
}
ACT_CODES = {"none": 0, "relu": 1, "relu6": 2}
PAD_CODES = {"same": 0, "valid": 1}
DT_I8, DT_I32, DT_F32 = 0, 1, 2


def _s(b: bytearray, s: str) -> None:
    raw = s.encode()
    b += struct.pack("<H", len(raw))
    b += raw


def _tensor(
    b: bytearray,
    name: str,
    dtype: int,
    dims: tuple[int, ...],
    scale: float,
    zero_point: int,
    data: bytes = b"",
) -> None:
    _s(b, name)
    b += struct.pack("<BB", dtype, len(dims))
    for d in dims:
        b += struct.pack("<I", d)
    b += struct.pack("<fi", scale, zero_point)
    b += struct.pack("<Q", len(data))
    b += data


def _op(b: bytearray, opcode: int, version: int, ins: list[int], outs: list[int], opts: bytes) -> None:
    b += struct.pack("<BI", opcode, version)
    b += struct.pack("<B", len(ins))
    for i in ins:
        b += struct.pack("<i", i)
    b += struct.pack("<B", len(outs))
    for o in outs:
        b += struct.pack("<i", o)
    b += struct.pack("<H", len(opts))
    b += opts


def serialize_mfb(qm: QuantizedModel) -> bytes:
    """Serialize a quantized model to MFB bytes."""
    model = qm.model
    shapes = layer_shapes(model)

    tensors = bytearray()
    ops = bytearray()
    n_tensors = 0

    def add_tensor(name, dtype, dims, scale, zp, data=b"") -> int:
        nonlocal n_tensors
        _tensor(tensors, name, dtype, tuple(int(d) for d in dims), float(scale), int(zp), data)
        n_tensors += 1
        return n_tensors - 1

    qin0 = qm.layers[0]["in"] if qm.layers else None
    in_idx = add_tensor("serving_default_input:0", DT_I8, (1, *model.input_shape), qin0.scale, qin0.zero_point)
    cur = in_idx

    n_ops = 0
    for li, (layer, lq) in enumerate(zip(model.layers, qm.layers)):
        op = layer["op"]
        out_shape = (1, *shapes[li + 1])
        qo = lq["out"]
        ins: list[int] = [cur]
        if lq.get("w_q") is not None:
            w = np.asarray(lq["w_q"], np.int8)
            bia = np.asarray(lq["b_q"], np.int32)
            widx = add_tensor(
                f"{model.name}/layer{li}/weights", DT_I8, w.shape,
                lq["wq"].scale, lq["wq"].zero_point, w.tobytes(),
            )
            bidx = add_tensor(
                f"{model.name}/layer{li}/bias", DT_I32, bia.shape,
                lq["bq"].scale, lq["bq"].zero_point, bia.tobytes(),
            )
            ins += [widx, bidx]
        out_idx = add_tensor(f"{model.name}/layer{li}/out", DT_I8, out_shape, qo.scale, qo.zero_point)

        if op == "fully_connected":
            opts = struct.pack("<B", ACT_CODES[layer["act"]])
        elif op == "conv2d":
            opts = struct.pack(
                "<BBBB", layer["stride"][0], layer["stride"][1],
                PAD_CODES[layer["padding"]], ACT_CODES[layer["act"]],
            )
        elif op == "depthwise_conv2d":
            opts = struct.pack(
                "<BBBBI", layer["stride"][0], layer["stride"][1],
                PAD_CODES[layer["padding"]], ACT_CODES[layer["act"]], layer["mult"],
            )
        elif op == "average_pool2d":
            opts = struct.pack(
                "<BBBBBB", layer["filter"][0], layer["filter"][1],
                layer["stride"][0], layer["stride"][1],
                PAD_CODES[layer["padding"]], 0,
            )
        elif op == "reshape":
            tgt = shapes[li + 1]
            opts = struct.pack("<B", len(tgt)) + b"".join(struct.pack("<I", d) for d in tgt)
        elif op == "softmax":
            opts = struct.pack("<f", 1.0)
        else:
            raise ValueError(op)
        _op(ops, OPCODES[op], 1, ins, [out_idx], opts)
        n_ops += 1
        cur = out_idx

    out = bytearray()
    out += b"MFB1"
    out += struct.pack("<I", 1)
    _s(out, "microflow-repro exporter 0.1 (jax)")
    out += struct.pack("<I", n_tensors)
    out += tensors
    out += struct.pack("<I", n_ops)
    out += ops
    out += struct.pack("<B", 1) + struct.pack("<i", in_idx)
    out += struct.pack("<B", 1) + struct.pack("<i", cur)
    _s(out, f'{{"model":"{model.name}","params":{sum(1 for l in qm.layers if l.get("w_q") is not None)} layers with weights"}}')
    return bytes(out)


def write_mfb(qm: QuantizedModel, path: str) -> int:
    data = serialize_mfb(qm)
    with open(path, "wb") as f:
        f.write(data)
    return len(data)


# ---------------------------------------------------------------------------
# MDS datasets
# ---------------------------------------------------------------------------

def serialize_mds(ds: D.Dataset) -> bytes:
    """MDS1: name | per-sample dims | label kind/dim | n | X f32 | Y f32/i32."""
    b = bytearray()
    b += b"MDS1"
    b += struct.pack("<I", 1)
    _s(b, ds.name)
    sample = ds.x.shape[1:]
    b += struct.pack("<B", len(sample))
    for d in sample:
        b += struct.pack("<I", d)
    if ds.is_classification:
        b += struct.pack("<BI", 1, 1)
    else:
        b += struct.pack("<BI", 0, ds.y.shape[1])
    b += struct.pack("<I", ds.n)
    b += np.ascontiguousarray(ds.x, np.float32).tobytes()
    if ds.is_classification:
        b += np.ascontiguousarray(ds.y, np.int32).tobytes()
    else:
        b += np.ascontiguousarray(ds.y, np.float32).tobytes()
    return bytes(b)


def write_mds(ds: D.Dataset, path: str) -> int:
    data = serialize_mds(ds)
    with open(path, "wb") as f:
        f.write(data)
    return len(data)


# ---------------------------------------------------------------------------
# GLD golden vectors (cross-implementation bit-exactness checks)
# ---------------------------------------------------------------------------

def serialize_golden(x_q: np.ndarray, y_q: np.ndarray) -> bytes:
    """GLD1: n | in dims | out dims | int8 X | int8 Y (batch-major)."""
    b = bytearray()
    b += b"GLD1"
    b += struct.pack("<I", 1)
    b += struct.pack("<I", x_q.shape[0])
    b += struct.pack("<B", x_q.ndim - 1)
    for d in x_q.shape[1:]:
        b += struct.pack("<I", d)
    b += struct.pack("<B", y_q.ndim - 1)
    for d in y_q.shape[1:]:
        b += struct.pack("<I", d)
    b += np.ascontiguousarray(x_q, np.int8).tobytes()
    b += np.ascontiguousarray(y_q, np.int8).tobytes()
    return bytes(b)


def write_golden(x_q: np.ndarray, y_q: np.ndarray, path: str) -> int:
    data = serialize_golden(x_q, y_q)
    with open(path, "wb") as f:
        f.write(data)
    return len(data)
